//! Experiment drivers — one per table/figure of the paper's evaluation.
//!
//! Each driver builds a fresh testbed, runs the paper's workload shape and
//! returns printable rows; the `rust/benches/*.rs` binaries and the
//! `scispace bench` CLI subcommand are thin wrappers. Dataset and cache
//! sizes are scaled down together (the paper's 375 GB exists to defeat
//! caching; we shrink the caches instead and document it in
//! EXPERIMENTS.md) — the *shape* of each result is the reproduction
//! target, not absolute MB/s.

use crate::api::{Op, OpResult};
use crate::db::Value;
use crate::engine::{Engine, SchedMode};
use crate::meu;
use crate::obs::metrics::Metrics;
use crate::sds::{self, ExtractionMode, Query, Sds, SdsConfig};
use crate::shdf;
use crate::simnet::{NetConfig, Network};
use crate::util::json::Json;
use crate::util::timer::percentile_sorted as percentile;
use crate::util::units::{fmt_bytes, fmt_secs};
use crate::workload::{self, IorConfig, ModisConfig};
use crate::workspace::{AccessMode, Testbed, TestbedConfig};
use crate::xfer::{
    run_flows, run_queue, CongestionConfig, DigestSinks, FaultInjector, PathStateTable, Priority,
    TransferQueue, TransferReport, TransferRequest, TuneConfig, XferConfig, XferEngine,
};

/// Build the scaled bench testbed (see module docs).
pub fn bench_testbed() -> Testbed {
    Testbed::build(bench_config())
}

/// The scaled bench configuration.
pub fn bench_config() -> TestbedConfig {
    let mut cfg = TestbedConfig::paper_default();
    // scale caches so tens-of-MB runs reach flush/thrash steady-state
    // like the paper's 375 GB did
    cfg.lustre.oss_write_cache = 4 << 20;
    cfg.lustre.oss_read_cache = 96 << 20;
    cfg.nfs.write_cache = 2 << 20;
    cfg.nfs.read_cache = 48 << 20;
    cfg
}

/// Direction of an IOR experiment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IorOp {
    /// Sequential write phase.
    Write,
    /// Sequential read phase (after a write + cache drop).
    Read,
}

/// One Fig. 7 / Fig. 8 row: throughput of the three systems.
#[derive(Debug, Clone)]
pub struct ThroughputRow {
    /// X value (block size for Fig. 7; collaborator count for Fig. 8).
    pub x: u64,
    /// UnionFS-style baseline, MB/s.
    pub baseline: f64,
    /// SCISPACE workspace, MB/s.
    pub scispace: f64,
    /// SCISPACE-LW native access, MB/s.
    pub lw: f64,
}

impl ThroughputRow {
    /// LW improvement over the better of baseline/scispace, percent.
    pub fn lw_gain_pct(&self) -> f64 {
        let best = self.baseline.max(self.scispace);
        if best <= 0.0 {
            return 0.0;
        }
        (self.lw - best) / best * 100.0
    }
}

fn run_ior(mode: AccessMode, op: IorOp, block: u64, n_collabs: usize, per_collab: u64) -> f64 {
    let mut tb = bench_testbed();
    for i in 0..n_collabs {
        tb.register(&format!("c{i}"), i % tb.cfg.n_dcs);
    }
    let cfg = IorConfig { block_size: block, bytes_per_collab: per_collab, n_collabs, mode };
    match op {
        IorOp::Write => workload::ior_write(&mut tb, &cfg).mbps,
        IorOp::Read => {
            // populate with large blocks, then measure cold reads
            let wcfg = IorConfig { block_size: 1 << 20, ..cfg.clone() };
            workload::ior_write(&mut tb, &wcfg);
            tb.drop_caches_and_reset();
            workload::ior_read(&mut tb, &cfg).mbps
        }
    }
}

/// Fig. 7: single collaborator, block-size sweep.
pub fn fig7(op: IorOp, blocks: &[u64], per_collab: u64) -> Vec<ThroughputRow> {
    blocks
        .iter()
        .map(|&bs| ThroughputRow {
            x: bs,
            baseline: run_ior(AccessMode::Baseline, op, bs, 1, per_collab),
            scispace: run_ior(AccessMode::Scispace, op, bs, 1, per_collab),
            lw: run_ior(AccessMode::ScispaceLw, op, bs, 1, per_collab),
        })
        .collect()
}

/// Fig. 8: 512 KB blocks, collaborator sweep.
pub fn fig8(op: IorOp, collabs: &[usize], per_collab: u64) -> Vec<ThroughputRow> {
    collabs
        .iter()
        .map(|&n| ThroughputRow {
            x: n as u64,
            baseline: run_ior(AccessMode::Baseline, op, 512 << 10, n, per_collab),
            scispace: run_ior(AccessMode::Scispace, op, 512 << 10, n, per_collab),
            lw: run_ior(AccessMode::ScispaceLw, op, 512 << 10, n, per_collab),
        })
        .collect()
}

/// One Fig. 9a row: time to create N zero-size files (+ MEU export).
#[derive(Debug, Clone)]
pub struct MeuRow {
    /// File count.
    pub files: u64,
    /// Baseline (workspace FUSE + all-branch metadata) seconds.
    pub baseline_s: f64,
    /// SCISPACE-LW (native creates only) seconds.
    pub lw_s: f64,
    /// SCISPACE-LW + MEU export seconds.
    pub lw_meu_s: f64,
}

/// Fig. 9a: MEU cost vs file count (zero-size files, §IV-D).
pub fn fig9a(counts: &[u64]) -> Vec<MeuRow> {
    counts
        .iter()
        .map(|&n| {
            // baseline: every create pays FUSE + all-branch metadata
            let mut tb = bench_testbed();
            tb.register("c0", 0);
            let mut sess = tb.session(0);
            for i in 0..n {
                sess.write(&format!("/meu/d{}/f{i}", i / 1000))
                    .mode(AccessMode::Baseline)
                    .submit()
                    .expect("create");
            }
            let baseline_s = tb.now(0);

            // LW: native creates
            let mut tb = bench_testbed();
            tb.register("c0", 0);
            let mut sess = tb.session(0);
            for i in 0..n {
                sess.write(&format!("/meu/d{}/f{i}", i / 1000))
                    .mode(AccessMode::ScispaceLw)
                    .submit()
                    .expect("create");
            }
            let lw_s = tb.now(0);

            // LW + MEU export of all files
            let rep = meu::export(&mut tb, 0, "/meu", None).expect("export");
            assert_eq!(rep.exported as u64, n);
            MeuRow { files: n, baseline_s, lw_s, lw_meu_s: rep.finished_at }
        })
        .collect()
}

/// One Fig. 9b row: extraction-mode time for a given attribute count.
#[derive(Debug, Clone)]
pub struct SdsModeRow {
    /// Attributes indexed per file.
    pub attrs: usize,
    /// Inline-Sync total collaborator time, seconds.
    pub inline_sync_s: f64,
    /// Inline-Async total collaborator time (extraction off-path), seconds.
    pub inline_async_s: f64,
    /// LW-Offline total collaborator time, seconds.
    pub lw_offline_s: f64,
}

fn corpus_with_attrs(n_files: usize, n_attrs: usize) -> Vec<(String, shdf::ShdfFile)> {
    let mut corpus = workload::modis_corpus(&ModisConfig { n_files, elems_per_file: 32_768, seed: 7 });
    for (_, f) in corpus.iter_mut() {
        // pad to the requested attribute count with user-defined attrs
        let have = f.attrs.len();
        for k in have..n_attrs {
            f.attr(&format!("user_attr_{k}"), Value::Int(k as i64));
        }
        f.attrs.truncate(n_attrs);
    }
    corpus
}

/// Fig. 9b: extraction modes, 4 collaborators, 5 vs 20 attributes.
pub fn fig9b(attr_counts: &[usize], files_per_collab: usize) -> Vec<SdsModeRow> {
    attr_counts
        .iter()
        .map(|&na| {
            let corpus = corpus_with_attrs(files_per_collab * 4, na);
            let run = |mode: ExtractionMode| -> f64 {
                let mut tb = bench_testbed();
                for i in 0..4 {
                    tb.register(&format!("c{i}"), i % 2);
                }
                let mut sds = Sds::new(tb.dtns.len(), SdsConfig::default());
                for (i, (path, f)) in corpus.iter().enumerate() {
                    let c = i % 4;
                    let p = format!("/c{c}{path}");
                    tb.session(c)
                        .write_indexed(&mut sds, &p, f)
                        .extraction(mode)
                        .submit()
                        .expect("write");
                }
                match mode {
                    ExtractionMode::LwOffline => {
                        // offline indexing runs on the DTN, off the
                        // collaborators' path; completion = write makespan
                        for c in 0..4 {
                            sds::offline_index(&mut tb, &mut sds, c, "/", None).expect("index");
                        }
                    }
                    ExtractionMode::InlineAsync => {
                        sds::process_queue(&mut tb, &mut sds, None).expect("queue");
                    }
                    ExtractionMode::InlineSync => {}
                }
                (0..4).map(|c| tb.now(c)).fold(0.0, f64::max)
            };
            SdsModeRow {
                attrs: na,
                inline_sync_s: run(ExtractionMode::InlineSync),
                inline_async_s: run(ExtractionMode::InlineAsync),
                lw_offline_s: run(ExtractionMode::LwOffline),
            }
        })
        .collect()
}

/// One Table II row: query latency per hit ratio for one attribute.
#[derive(Debug, Clone)]
pub struct QueryLatencyRow {
    /// Attribute under query.
    pub attr: &'static str,
    /// (hit_ratio_pct, avg latency seconds).
    pub latencies: Vec<(u64, f64)>,
}

/// Table II: search latency vs hit ratio for the four paper attributes.
/// `n_tuples` controls shard population; `queries` per ratio.
pub fn table2(n_tuples: usize, queries: usize) -> Vec<QueryLatencyRow> {
    let attrs: [(&'static str, bool); 4] = [
        ("Location", true),
        ("Instrument", true),
        ("Date", true),
        ("DayNight", false),
    ];
    let ratios = [0u64, 25, 50, 75, 100];
    attrs
        .iter()
        .map(|&(attr, is_text)| {
            let mut tb = bench_testbed();
            for i in 0..4 {
                tb.register(&format!("c{i}"), i % 2);
            }
            let mut sds = Sds::new(tb.dtns.len(), SdsConfig::default());
            // populate with nested-prefix quartile values so one query can
            // match exactly 0/25/50/75/100% of tuples:
            //   text quartile q (1..4) -> "m" repeated q times; the LIKE
            //   pattern "m"*k + "%" matches quartiles >= k, i.e. (5-k)/4
            //   of the shard. int quartile q -> Value::Int(q); "< k"
            //   matches (k-1)/4.
            for i in 0..n_tuples {
                let path = format!("/t2/f{i}.shdf");
                tb.session(0)
                    .write(&path)
                    .len(64)
                    .mode(AccessMode::ScispaceLw)
                    .submit()
                    .expect("create");
                let q = i * 4 / n_tuples + 1; // quartile 1..4
                let v = if is_text {
                    Value::Text("m".repeat(q))
                } else {
                    Value::Int(q as i64)
                };
                tb.session(0).tag(&mut sds, &path, attr, v).submit().expect("tag");
            }
            tb.quiesce(); // population backlog must not pollute latencies
            let latencies = ratios
                .iter()
                .map(|&r| {
                    let mut total = 0.0;
                    for qi in 0..queries {
                        let c = qi % 4;
                        // hit ratio r%: see population comment above
                        let q = if r == 0 {
                            if is_text {
                                Query::parse(&format!("{attr} = nonexistent")).unwrap()
                            } else {
                                Query::parse(&format!("{attr} < 1")).unwrap()
                            }
                        } else if is_text {
                            let k = 5 - (r / 25) as usize; // 25%->4 m's, 100%->1
                            Query {
                                attr: attr.to_string(),
                                op: sds::Op::Like,
                                value: Value::Text(format!("{}%", "m".repeat(k))),
                            }
                        } else {
                            let k = r / 25 + 1; // matches quartiles < k
                            Query::parse(&format!("{attr} < {k}")).unwrap()
                        };
                        let res =
                            tb.session(c).query_parsed(&mut sds, q).submit().expect("query");
                        match res {
                            OpResult::Hits { latency_s, .. } => total += latency_s,
                            other => panic!("expected Hits, got {other:?}"),
                        }
                    }
                    (r, total / queries as f64)
                })
                .collect();
            QueryLatencyRow { attr, latencies }
        })
        .collect()
}

/// One Fig. 9c row: end-to-end H5Diff collaboration.
#[derive(Debug, Clone)]
pub struct End2EndRow {
    /// Files involved in the analysis.
    pub files: usize,
    /// Baseline: filename search + migrate + run, seconds.
    pub baseline_s: f64,
    /// SCISPACE: attribute query + run in place, seconds.
    pub scispace_s: f64,
    /// Differences found (sanity: both paths must agree).
    pub n_diff: u64,
}

/// Fig. 9c: end-to-end analysis (H5Diff) — baseline migrates datasets to
/// the local DC first; SCISPACE queries and diffs in place. `diff_fn`
/// lets callers supply the PJRT engine (falls back to the CPU core).
pub fn fig9c(
    file_counts: &[usize],
    mut diff_fn: Option<&mut dyn FnMut(&[f32], &[f32], f32) -> (u64, f32, f64)>,
) -> Vec<End2EndRow> {
    file_counts
        .iter()
        .map(|&nf| {
            let corpus = workload::modis_corpus(&ModisConfig { n_files: nf, elems_per_file: 8192, seed: 11 });
            // pairs: even = reference, odd = comparison
            let mut tb = bench_testbed();
            let remote_writer = tb.register("writer", 1);
            let analyst = tb.register("analyst", 0);
            workload::load_corpus(&mut tb, remote_writer, &corpus, AccessMode::Scispace);
            let mut sds = Sds::new(tb.dtns.len(), SdsConfig::default());
            sds::offline_index(&mut tb, &mut sds, remote_writer, "/modis", None).expect("index");
            tb.drop_caches_and_reset();

            // ---- baseline: filename search (exhaustive ls) + migrate + diff
            let t0 = tb.now(analyst);
            let listing = tb.session(analyst).ls("/modis").submit().expect("ls").entries()
                .expect("listing"); // exhaustive namespace walk
            // filename-based search cannot use attributes: the analyst
            // lists everything and migrates all candidate files
            let mut migrated: Vec<(String, Vec<u8>)> = Vec::new();
            for m in &listing {
                let mut sess = tb.session(analyst);
                let raw =
                    sess.read(&m.path).len(m.size).submit().expect("read").data().expect("data");
                // store a local copy (the migration the paper describes)
                let local = format!("/local{}", m.path);
                sess.write(&local)
                    .data(&raw)
                    .mode(AccessMode::ScispaceLw)
                    .submit()
                    .expect("migrate");
                migrated.push((local, raw));
            }
            let mut n_diff_base = 0u64;
            let mut compute = |a: &[f32], b: &[f32]| -> u64 {
                match diff_fn.as_deref_mut() {
                    Some(f) => f(a, b, 0.5).0,
                    None => shdf::diff_core(a, b, 0.5).0,
                }
            };
            for pair in migrated.chunks(2) {
                if pair.len() < 2 {
                    continue;
                }
                let fa: shdf::ShdfFile = crate::msg::Wire::from_bytes(&pair[0].1).expect("parse");
                let fb: shdf::ShdfFile = crate::msg::Wire::from_bytes(&pair[1].1).expect("parse");
                if let (Some(da), Some(db)) = (fa.get_dataset("sst"), fb.get_dataset("sst")) {
                    n_diff_base += compute(&da.data, &db.data);
                    // charge compute cost on the analyst's clock
                    tb.session(analyst).advance((da.data.len() as f64) / 2.0e9 * 2.0);
                }
            }
            let baseline_s = tb.now(analyst) - t0;

            // ---- scispace: attribute query + in-place diff (no migration)
            tb.drop_caches_and_reset();
            let t0 = tb.now(analyst);
            let hits = tb
                .session(analyst)
                .query(&mut sds, "Instrument like MODIS%")
                .submit()
                .expect("query")
                .files()
                .expect("hits");
            let mut n_diff_sci = 0u64;
            let mut raws: Vec<Vec<u8>> = Vec::new();
            for h in &hits {
                // whole-file read (the builder sizes it via the metadata);
                // a lost record is skipped, any other failure is a bug
                match tb.session(analyst).read(h).submit() {
                    Ok(res) => raws.push(res.data().expect("data")),
                    Err(crate::api::ScispaceError::NoSuchFile { .. }) => {}
                    Err(e) => panic!("fig9c read failed: {e}"),
                }
            }
            for pair in raws.chunks(2) {
                if pair.len() < 2 {
                    continue;
                }
                let fa: shdf::ShdfFile = crate::msg::Wire::from_bytes(&pair[0]).expect("parse");
                let fb: shdf::ShdfFile = crate::msg::Wire::from_bytes(&pair[1]).expect("parse");
                if let (Some(da), Some(db)) = (fa.get_dataset("sst"), fb.get_dataset("sst")) {
                    n_diff_sci += compute(&da.data, &db.data);
                    tb.session(analyst).advance((da.data.len() as f64) / 2.0e9 * 2.0);
                }
            }
            let scispace_s = tb.now(analyst) - t0;
            End2EndRow { files: nf, baseline_s, scispace_s, n_diff: n_diff_sci.max(n_diff_base) }
        })
        .collect()
}

/// One `fig_collab_concurrency` row: typed-op latency under N
/// concurrent collaborators submitted through `Testbed::run_batch`.
#[derive(Debug, Clone)]
pub struct CollabRow {
    /// Concurrent collaborators in the batch.
    pub collabs: usize,
    /// Serial ops each collaborator submitted.
    pub ops_per_collab: usize,
    /// Median per-op latency, virtual seconds.
    pub p50_s: f64,
    /// 99th-percentile per-op latency, virtual seconds.
    pub p99_s: f64,
    /// Mean per-op latency, virtual seconds.
    pub mean_s: f64,
    /// Batch makespan (first submit to last completion), seconds.
    pub makespan_s: f64,
}

/// The multi-user contention scenario the Session API makes
/// first-class: N collaborators (split across the data centers) each
/// stream `bytes`-sized remote reads through one `run_batch`, all
/// contending on the shared inter-DC link. The WAN is provisioned as
/// the bottleneck (geo regime), so per-op latency grows with the
/// collaborator count — processor sharing, not queueing collapse.
pub fn fig_collab_concurrency(counts: &[usize], ops_per_collab: usize, bytes: u64) -> Vec<CollabRow> {
    counts
        .iter()
        .map(|&n| {
            let mut cfg = TestbedConfig::paper_default();
            // geo regime: a 400 MB/s, 5 ms WAN is what the readers share
            cfg.net.wan_bw = 400e6;
            cfg.net.wan_latency_s = 5e-3;
            let mut tb = Testbed::build(cfg);
            let readers: Vec<usize> =
                (0..n).map(|i| tb.register(&format!("r{i}"), i % 2)).collect();
            // one publisher per DC so every reader has a remote granule
            let pubs: Vec<usize> = (0..2).map(|d| tb.register(&format!("pub{d}"), d)).collect();
            for (i, &r) in readers.iter().enumerate() {
                let remote_dc = (tb.collabs[r].dc + 1) % 2;
                let path = format!("/collab/shared/g{i}.dat");
                tb.session(pubs[remote_dc]).write(&path).len(bytes).submit().expect("populate");
            }
            tb.quiesce();
            let start = tb.now(readers[0]);

            let mut ops: Vec<(usize, Op)> = Vec::new();
            let mut owner_of: Vec<usize> = Vec::new();
            for _ in 0..ops_per_collab {
                for (i, &r) in readers.iter().enumerate() {
                    ops.push((
                        r,
                        Op::Read {
                            path: format!("/collab/shared/g{i}.dat"),
                            offset: 0,
                            len: Some(bytes),
                            mode: AccessMode::Scispace,
                        },
                    ));
                    owner_of.push(r);
                }
            }
            let results = tb.run_batch(ops);

            // a collaborator's ops are serial, so its k-th latency is the
            // gap between consecutive completions
            let mut prev: Vec<f64> = vec![start; tb.collabs.len()];
            let mut lats: Vec<f64> = Vec::new();
            let mut makespan = 0.0f64;
            for (res, &r) in results.iter().zip(&owner_of) {
                assert!(res.is_ok(), "collab bench op failed: {:?}", res.err());
                let f = res.finished_at();
                lats.push(f - prev[r]);
                prev[r] = f;
                makespan = makespan.max(f - start);
            }
            lats.sort_by(f64::total_cmp);
            CollabRow {
                collabs: n,
                ops_per_collab,
                p50_s: percentile(&lats, 0.50),
                p99_s: percentile(&lats, 0.99),
                mean_s: lats.iter().sum::<f64>() / lats.len().max(1) as f64,
                makespan_s: makespan,
            }
        })
        .collect()
}

/// The asymmetric-op-size scenario of `fig_collab_concurrency`: one
/// collaborator's interactive read concurrent with another's
/// multi-hundred-MB bulk replicate on disjoint payload links.
#[derive(Debug, Clone)]
pub struct AsymmetricRow {
    /// Bulk replicate payload, bytes.
    pub bulk_bytes: u64,
    /// Interactive read payload, bytes.
    pub read_bytes: u64,
    /// Interactive read latency with no concurrent bulk op, seconds.
    pub read_solo_s: f64,
    /// Interactive read latency concurrent with the bulk op, seconds.
    pub read_concurrent_s: f64,
    /// The bulk replicate's own latency in the concurrent run, seconds.
    pub bulk_s: f64,
}

impl AsymmetricRow {
    /// Concurrent-to-solo latency ratio of the interactive read
    /// (~1.0 = no cross-stall; the old wave executor had no such
    /// guarantee for asymmetric op sizes).
    pub fn stall_ratio(&self) -> f64 {
        if self.read_solo_s > 0.0 {
            self.read_concurrent_s / self.read_solo_s
        } else {
            f64::NAN
        }
    }
}

/// Asymmetric batch scenario: alice (dc0) replicates a `bulk_bytes`
/// granule dc0 -> dc1 while bob (dc2) issues a small `read_bytes` read
/// of a dc2-local file — disjoint payload links, wildly different op
/// sizes, one `run_batch`. Event-driven per-collaborator admission
/// keeps bob at his solo latency; the makespan is alice's.
pub fn fig_collab_asymmetric(bulk_bytes: u64, read_bytes: u64) -> AsymmetricRow {
    let bed = || {
        let mut cfg = TestbedConfig::paper_default();
        cfg.n_dcs = 3;
        let mut tb = Testbed::build(cfg);
        let alice = tb.register("alice", 0);
        let bob = tb.register("bob", 2);
        tb.session(alice).write("/asym/big.dat").len(bulk_bytes).submit().expect("populate");
        tb.session(bob).write("/asym/local.dat").len(read_bytes).submit().expect("populate");
        tb.quiesce();
        (tb, alice, bob)
    };
    let read_op = || Op::Read {
        path: "/asym/local.dat".into(),
        offset: 0,
        len: Some(read_bytes),
        mode: AccessMode::Scispace,
    };
    let read_solo_s = {
        let (mut tb, _alice, bob) = bed();
        let start = tb.now(bob);
        let results = tb.run_batch(vec![(bob, read_op())]);
        assert!(results[0].is_ok(), "asymmetric solo read failed: {:?}", results[0].err());
        results[0].finished_at() - start
    };
    let (mut tb, alice, bob) = bed();
    let start = tb.now(bob);
    let results = tb.run_batch(vec![
        (alice, Op::Replicate { path: "/asym/big.dat".into(), dst_dc: 1 }),
        (bob, read_op()),
    ]);
    assert!(results.iter().all(|r| r.is_ok()), "asymmetric batch failed: {results:?}");
    AsymmetricRow {
        bulk_bytes,
        read_bytes,
        read_solo_s,
        read_concurrent_s: results[1].finished_at() - start,
        bulk_s: results[0].finished_at() - start,
    }
}

/// Print the asymmetric scenario row.
pub fn print_asymmetric(row: &AsymmetricRow) {
    println!("\n== Fig collab-asymmetric: small read vs concurrent bulk replicate ==");
    println!(
        "bulk {} | read {}: solo {} concurrent {} (stall ratio {:.4}), bulk {}",
        fmt_bytes(row.bulk_bytes),
        fmt_bytes(row.read_bytes),
        fmt_secs(row.read_solo_s),
        fmt_secs(row.read_concurrent_s),
        row.stall_ratio(),
        fmt_secs(row.bulk_s)
    );
}

/// Print `fig_collab_concurrency` rows.
pub fn print_collab(rows: &[CollabRow]) {
    println!("\n== Fig collab-concurrency: run_batch remote reads on one WAN ==");
    println!(
        "{:>10} {:>8} {:>12} {:>12} {:>12} {:>12}",
        "collabs", "ops", "op-p50", "op-p99", "op-mean", "makespan"
    );
    for r in rows {
        println!(
            "{:>10} {:>8} {:>12} {:>12} {:>12} {:>12}",
            r.collabs,
            r.ops_per_collab,
            fmt_secs(r.p50_s),
            fmt_secs(r.p99_s),
            fmt_secs(r.mean_s),
            fmt_secs(r.makespan_s)
        );
    }
    if let (Some(first), Some(last)) = (rows.first(), rows.last()) {
        if last.collabs > first.collabs && first.p50_s > 0.0 {
            println!(
                "contention: p50 grows {:.1}x from {} to {} collaborators (shared WAN)",
                last.p50_s / first.p50_s,
                first.collabs,
                last.collabs
            );
        }
    }
}

/// Machine-readable `BENCH_collab.json` payload: p50/p99 per-op latency
/// per concurrency level plus the asymmetric-op-size scenario, for CI
/// perf tracking.
pub fn collab_json(rows: &[CollabRow], asym: &AsymmetricRow) -> Json {
    use std::collections::BTreeMap;
    let out: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("collabs".to_string(), Json::Num(r.collabs as f64));
            m.insert("ops_per_collab".to_string(), Json::Num(r.ops_per_collab as f64));
            m.insert("p50_s".to_string(), Json::Num(r.p50_s));
            m.insert("p99_s".to_string(), Json::Num(r.p99_s));
            m.insert("mean_s".to_string(), Json::Num(r.mean_s));
            m.insert("makespan_s".to_string(), Json::Num(r.makespan_s));
            Json::Obj(m)
        })
        .collect();
    let mut a = BTreeMap::new();
    a.insert("scenario".to_string(), Json::Str("asymmetric".to_string()));
    a.insert("bulk_bytes".to_string(), Json::Num(asym.bulk_bytes as f64));
    a.insert("read_bytes".to_string(), Json::Num(asym.read_bytes as f64));
    a.insert("read_solo_s".to_string(), Json::Num(asym.read_solo_s));
    a.insert("read_concurrent_s".to_string(), Json::Num(asym.read_concurrent_s));
    a.insert("bulk_s".to_string(), Json::Num(asym.bulk_s));
    a.insert("stall_ratio".to_string(), Json::Num(asym.stall_ratio()));
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("collab".to_string()));
    top.insert("rows".to_string(), Json::Arr(out));
    top.insert("asymmetric".to_string(), Json::Obj(a));
    Json::Obj(top)
}

/// One `fig_xfer_streams` row: stream-count sweep on the fixed WAN.
#[derive(Debug, Clone)]
pub struct XferStreamRow {
    /// Streams striped over the transfer.
    pub streams: usize,
    /// Virtual transfer time, seconds.
    pub secs: f64,
    /// Goodput, MB/s.
    pub mbps: f64,
}

/// Sweep stream counts for one `total`-byte DC0 -> DC1 transfer on the
/// paper WAN. The expected shape (and the acceptance check of the xfer
/// engine): time strictly decreases with stream count while per-chunk
/// latency dominates, then plateaus at the link byte-serialization
/// floor.
pub fn fig_xfer_streams(total: u64, stream_counts: &[usize]) -> Vec<XferStreamRow> {
    fig_xfer_streams_cfg(total, stream_counts, &XferConfig::default())
}

/// [`fig_xfer_streams`] with explicit engine tuning (chunk size etc.);
/// only the stream count varies across rows.
pub fn fig_xfer_streams_cfg(
    total: u64,
    stream_counts: &[usize],
    base: &XferConfig,
) -> Vec<XferStreamRow> {
    stream_counts
        .iter()
        .map(|&s| {
            let mut env = Engine::new();
            let mut net = Network::build(&mut env, &NetConfig::paper_default(), 2);
            let engine = XferEngine::new(XferConfig { n_streams: s, ..base.clone() });
            let req = TransferRequest {
                id: s as u64,
                owner: "bench".into(),
                src_dc: 0,
                dst_dc: 1,
                bytes: total,
                priority: Priority::Bulk,
                submitted_at: 0.0,
            };
            let rep = engine
                .transfer(&mut env, &mut net, &req, &mut FaultInjector::none(), 0.0)
                .expect("transfer");
            XferStreamRow { streams: s, secs: rep.seconds(), mbps: rep.mbps() }
        })
        .collect()
}

/// One `fig_xfer_streams_cc` row: stream-count sweep on the
/// congestion-managed geo WAN.
#[derive(Debug, Clone)]
pub struct XferCcRow {
    /// Streams striped over the transfer.
    pub streams: usize,
    /// Virtual transfer time, seconds.
    pub secs: f64,
    /// Goodput, MB/s.
    pub mbps: f64,
    /// Congestion losses the streams absorbed.
    pub losses: u64,
    /// Bytes re-queued for retransmission by those losses.
    pub retransmit_bytes: u64,
}

/// Stream-count sweep with AIMD congestion control on the geo WAN
/// ([`NetConfig::geo_default`]): each stream is a windowed flow, so
/// striping multiplies aggregate window growth *and* loss exposure.
/// Expected shape — the over-striping curve wide-area file systems
/// report: throughput rises while the aggregate window ceiling is below
/// the wire, peaks near saturation, then collapses as synthesized loss
/// and go-back retransmission eat the extra streams' gains. Contrast
/// with [`fig_xfer_streams`], whose lossless fair-share WAN only
/// plateaus.
pub fn fig_xfer_streams_cc(total: u64, stream_counts: &[usize]) -> Vec<XferCcRow> {
    stream_counts
        .iter()
        .map(|&s| {
            let mut env = Engine::new();
            let mut net = Network::build(&mut env, &NetConfig::geo_default(), 2);
            let cfg = XferConfig {
                n_streams: s,
                cc: crate::xfer::CongestionConfig::on(),
                ..XferConfig::default()
            };
            let req = TransferRequest {
                id: s as u64,
                owner: "bench".into(),
                src_dc: 0,
                dst_dc: 1,
                bytes: total,
                priority: Priority::Bulk,
                submitted_at: 0.0,
            };
            let rep = run_flows(&mut env, &mut net, &cfg, &[req], false).remove(0);
            let secs = rep.latency();
            XferCcRow {
                streams: s,
                secs,
                mbps: crate::util::units::mbps(total, secs),
                losses: rep.losses,
                retransmit_bytes: rep.retransmit_bytes,
            }
        })
        .collect()
}

/// Print `fig_xfer_streams_cc` rows.
pub fn print_xfer_streams_cc(total: u64, rows: &[XferCcRow]) {
    println!(
        "\n== Fig xfer-streams (congested): {} over the geo WAN (AIMD windows) ==",
        fmt_bytes(total)
    );
    println!("{:>8} {:>12} {:>12} {:>8} {:>12}", "streams", "time", "goodput", "losses", "retx");
    for r in rows {
        println!(
            "{:>8} {:>12} {:>9.1}MB/s {:>8} {:>12}",
            r.streams,
            fmt_secs(r.secs),
            r.mbps,
            r.losses,
            fmt_bytes(r.retransmit_bytes)
        );
    }
    if let (Some(peak), Some(last)) = (
        rows.iter().cloned().reduce(|a, b| if b.mbps > a.mbps { b } else { a }),
        rows.last(),
    ) {
        if last.streams != peak.streams {
            println!(
                "over-striping: peak {:.1} MB/s at {} streams, {:.1}% lower at {}",
                peak.mbps,
                peak.streams,
                (peak.mbps - last.mbps) / peak.mbps * 100.0,
                last.streams
            );
        }
    }
}

/// One `fig_xfer_mix` row: a transfer inside a concurrent mix.
#[derive(Debug, Clone)]
pub struct XferMixRow {
    /// Owning collaboration.
    pub owner: String,
    /// Priority class name.
    pub priority: &'static str,
    /// Payload bytes.
    pub bytes: u64,
    /// Completion time within the mix, seconds from mix start.
    pub finished_s: f64,
    /// Goodput over the transfer's own lifetime, MB/s.
    pub mbps: f64,
    /// Chunk deliveries that were retried.
    pub retried: u32,
    /// Peak concurrent transfers the WAN saw during the mix.
    pub wan_peak: u32,
}

/// Concurrent-transfer mix on one WAN: two bulk collaborations, one
/// interactive read and one scavenger sweep, drained through the
/// priority/fair-share scheduler. Shows (a) weighted bandwidth sharing
/// and (b) the interactive transfer finishing first despite equal size.
pub fn fig_xfer_mix(per_transfer: u64) -> Vec<XferMixRow> {
    let mut env = Engine::new();
    let mut net = Network::build(&mut env, &NetConfig::paper_default(), 2);
    let engine = XferEngine::new(XferConfig::default());
    let mut queue = TransferQueue::new();
    let mix = [
        ("climate", Priority::Bulk, per_transfer),
        ("genomics", Priority::Bulk, per_transfer),
        ("analyst", Priority::Interactive, per_transfer),
        ("archive", Priority::Scavenger, per_transfer / 2),
    ];
    for (i, (owner, prio, bytes)) in mix.iter().enumerate() {
        queue.submit(TransferRequest {
            id: i as u64,
            owner: owner.to_string(),
            src_dc: 0,
            dst_dc: 1,
            bytes: *bytes,
            priority: *prio,
            submitted_at: 0.0,
        });
    }
    let reports = run_queue(
        &engine,
        &mut env,
        &mut net,
        &mut queue,
        &mut FaultInjector::none(),
        0.0,
        mix.len(),
    )
    .expect("mix");
    let peak = net.wan_peak();
    reports
        .into_iter()
        .map(|r| XferMixRow {
            owner: r.owner.clone(),
            priority: r.priority.name(),
            bytes: r.bytes,
            finished_s: r.finished_at,
            mbps: r.mbps(),
            retried: r.retried_chunks,
            wan_peak: peak,
        })
        .collect()
}

/// One `fig_preempt` row: Interactive latency under Bulk background
/// load, with or without scheduler preemption.
#[derive(Debug, Clone)]
pub struct PreemptRow {
    /// Preemption enabled?
    pub preempt: bool,
    /// Median Interactive submission-to-completion latency, seconds.
    pub interactive_p50_s: f64,
    /// 99th-percentile Interactive latency, seconds.
    pub interactive_p99_s: f64,
    /// Mean Interactive latency, seconds.
    pub interactive_mean_s: f64,
    /// When the last Bulk transfer finished (the price paid), seconds.
    pub bulk_makespan_s: f64,
}

/// `fig_preempt`: Interactive arrivals against saturating Bulk
/// background traffic on one WAN, through the event-driven flow
/// scheduler — once with preemption off (classes share links by weight
/// only) and once with preemption on (an Interactive arrival pauses
/// every admitted Bulk flow mid-transfer). The ROADMAP's scheduler-
/// preemption item, made measurable: Interactive p50/p99 drop, Bulk
/// makespan grows.
pub fn fig_preempt(
    n_interactive: usize,
    interactive_bytes: u64,
    n_bulk: usize,
    bulk_bytes: u64,
) -> Vec<PreemptRow> {
    let wire = NetConfig::paper_default().wan_bw;
    // spread the interactive arrivals across the bulk work's wire time,
    // so every arrival lands while Bulk still saturates the WAN
    let span = (n_bulk as u64 * bulk_bytes) as f64 / wire;
    let mut reqs: Vec<TransferRequest> = Vec::new();
    for b in 0..n_bulk {
        reqs.push(TransferRequest {
            id: b as u64,
            owner: format!("bulk{b}"),
            src_dc: 0,
            dst_dc: 1,
            bytes: bulk_bytes,
            priority: Priority::Bulk,
            submitted_at: 0.0,
        });
    }
    for k in 0..n_interactive {
        reqs.push(TransferRequest {
            id: 1000 + k as u64,
            owner: format!("analyst{k}"),
            src_dc: 0,
            dst_dc: 1,
            bytes: interactive_bytes,
            priority: Priority::Interactive,
            submitted_at: span * (k as f64 + 0.5) / n_interactive as f64,
        });
    }
    [false, true]
        .iter()
        .map(|&preempt| {
            let mut env = Engine::new();
            let mut net = Network::build(&mut env, &NetConfig::paper_default(), 2);
            let reports = run_flows(&mut env, &mut net, &XferConfig::default(), &reqs, preempt);
            assert_eq!(reports.len(), reqs.len(), "every transfer must complete");
            let mut lats: Vec<f64> = reports
                .iter()
                .filter(|r| r.priority == Priority::Interactive)
                .map(|r| r.latency())
                .collect();
            lats.sort_by(f64::total_cmp);
            let bulk_makespan_s = reports
                .iter()
                .filter(|r| r.priority == Priority::Bulk)
                .map(|r| r.finished_at)
                .fold(0.0, f64::max);
            PreemptRow {
                preempt,
                interactive_p50_s: percentile(&lats, 0.50),
                interactive_p99_s: percentile(&lats, 0.99),
                interactive_mean_s: lats.iter().sum::<f64>() / lats.len().max(1) as f64,
                bulk_makespan_s,
            }
        })
        .collect()
}

/// Print `fig_preempt` rows.
pub fn print_preempt(rows: &[PreemptRow]) {
    println!("\n== Fig preempt: Interactive tail latency vs Bulk background ==");
    println!(
        "{:>10} {:>12} {:>12} {:>12} {:>14}",
        "preempt", "int-p50", "int-p99", "int-mean", "bulk-makespan"
    );
    for r in rows {
        println!(
            "{:>10} {:>12} {:>12} {:>12} {:>14}",
            if r.preempt { "on" } else { "off" },
            fmt_secs(r.interactive_p50_s),
            fmt_secs(r.interactive_p99_s),
            fmt_secs(r.interactive_mean_s),
            fmt_secs(r.bulk_makespan_s)
        );
    }
    if let (Some(off), Some(on)) =
        (rows.iter().find(|r| !r.preempt), rows.iter().find(|r| r.preempt))
    {
        println!(
            "p99 gain: {:.1}% lower with preemption (bulk pays {:.1}% longer makespan)",
            (off.interactive_p99_s - on.interactive_p99_s) / off.interactive_p99_s * 100.0,
            (on.bulk_makespan_s - off.bulk_makespan_s) / off.bulk_makespan_s * 100.0
        );
    }
}

/// One `fig_xfer_adaptive` row: a single WAN bulk transfer under a
/// fixed stream count or under the goodput-guided stream autotuner.
#[derive(Debug, Clone)]
pub struct XferAdaptiveRow {
    /// WAN scenario: `clean` (congestion-managed, lossless), `lossy`
    /// (the geo WAN's 20 ms loss knob armed) or `degrading` (lossy WAN
    /// plus interfering flows joining mid-transfer).
    pub scenario: &'static str,
    /// `fixed-N`, `adaptive-cold` (first run on an unknown path) or
    /// `adaptive` (warm-started from the learned per-path width).
    pub mode: String,
    /// Stream count the transfer opened with.
    pub streams_initial: usize,
    /// Stream count it ended at (== initial for fixed widths).
    pub streams_final: usize,
    /// Virtual transfer time, seconds.
    pub secs: f64,
    /// Goodput, MB/s.
    pub mbps: f64,
    /// Congestion losses the transfer's streams absorbed.
    pub losses: u64,
    /// Bytes those losses re-queued for retransmission.
    pub retransmit_bytes: u64,
    /// Stream-count increases the controller applied.
    pub widens: u32,
    /// Stream-count reductions the controller applied.
    pub sheds: u32,
}

fn adaptive_row(scenario: &'static str, mode: &str, rep: &TransferReport) -> XferAdaptiveRow {
    let t = rep.tune;
    XferAdaptiveRow {
        scenario,
        mode: mode.to_string(),
        streams_initial: t.map_or(rep.streams, |o| o.initial_streams),
        streams_final: t.map_or(rep.streams, |o| o.final_streams),
        secs: rep.seconds(),
        mbps: rep.mbps(),
        losses: rep.cc_losses,
        retransmit_bytes: rep.cc_retransmit_bytes,
        widens: t.map_or(0, |o| o.widens),
        sheds: t.map_or(0, |o| o.sheds),
    }
}

/// Run one measured DC0 -> DC1 transfer on a fresh 4-DC network.
/// `interfere` arms the degrading scenario: four windowed flows join
/// the shared WAN partway through (DC2 -> DC3 — same WAN hop, disjoint
/// LANs), so the path turns hostile mid-flight instead of being lossy
/// from the first chunk.
fn adaptive_scenario(
    netcfg: &NetConfig,
    cfg: &XferConfig,
    total: u64,
    interfere: bool,
    paths: &mut PathStateTable,
) -> TransferReport {
    use crate::engine::CcConfig;
    let mut env = Engine::new();
    let mut net = Network::build(&mut env, netcfg, 4);
    if interfere {
        let t_mid = 0.3 * total as f64 / netcfg.wan_bw;
        let path = net.flow_path(2, 3);
        for _ in 0..4 {
            env.start_windowed_flow(&path, total, t_mid, 1.0, &CcConfig::default());
        }
    }
    let engine = XferEngine::new(cfg.clone());
    let req = TransferRequest {
        id: 0,
        owner: "bench".into(),
        src_dc: 0,
        dst_dc: 1,
        bytes: total,
        priority: Priority::Bulk,
        submitted_at: 0.0,
    };
    engine
        .transfer_tuned(
            &mut env,
            &mut net,
            &req,
            &mut FaultInjector::none(),
            0.0,
            DigestSinks::default(),
            paths,
        )
        .expect("transfer")
}

/// Adaptive-vs-fixed stream-count comparison (the autotuner's
/// acceptance figure). For each WAN scenario, sweep fixed widths, then
/// run the autotuner three times over a shared per-path width table:
/// run 1 is reported as `adaptive-cold` (climbing from the default
/// width on an unknown path), run 3 as `adaptive` (warm-started at the
/// learned width — the steady state a long-lived collaboration sees).
/// The acceptance shape: warmed adaptive within 10% of the best fixed
/// width on the clean WAN, and strictly above the over-striped fixed
/// width on the lossy WAN — without per-scenario hand tuning.
pub fn fig_xfer_adaptive(total: u64, fixed_widths: &[usize]) -> Vec<XferAdaptiveRow> {
    let scenarios: [(&'static str, NetConfig, bool); 3] = [
        (
            "clean",
            NetConfig { wan_loss_detect_s: f64::INFINITY, ..NetConfig::geo_default() },
            false,
        ),
        ("lossy", NetConfig::geo_default(), false),
        ("degrading", NetConfig::geo_default(), true),
    ];
    let mut rows = Vec::new();
    for (name, netcfg, interfere) in scenarios {
        for &w in fixed_widths {
            let cfg =
                XferConfig { n_streams: w, cc: CongestionConfig::on(), ..XferConfig::default() };
            let mut scratch = PathStateTable::new();
            let rep = adaptive_scenario(&netcfg, &cfg, total, interfere, &mut scratch);
            rows.push(adaptive_row(name, &format!("fixed-{w}"), &rep));
        }
        let acfg = XferConfig {
            cc: CongestionConfig::on(),
            tune: TuneConfig::adaptive(),
            ..XferConfig::default()
        };
        let mut paths = PathStateTable::new();
        let mut last = None;
        for run in 0..3 {
            let rep = adaptive_scenario(&netcfg, &acfg, total, interfere, &mut paths);
            if run == 0 {
                rows.push(adaptive_row(name, "adaptive-cold", &rep));
            }
            last = Some(rep);
        }
        rows.push(adaptive_row(name, "adaptive", &last.expect("three runs")));
    }
    rows
}

/// Print `fig_xfer_adaptive` rows, grouped by scenario.
pub fn print_xfer_adaptive(total: u64, rows: &[XferAdaptiveRow]) {
    println!(
        "\n== Fig xfer-adaptive: {} per transfer, fixed widths vs autotuner ==",
        fmt_bytes(total)
    );
    let mut scenario = "";
    for r in rows {
        if r.scenario != scenario {
            scenario = r.scenario;
            println!("-- {scenario} WAN --");
            println!(
                "{:>14} {:>9} {:>12} {:>12} {:>8} {:>12}",
                "mode", "streams", "time", "goodput", "losses", "retx"
            );
        }
        let streams = if r.streams_initial == r.streams_final {
            format!("{}", r.streams_final)
        } else {
            format!("{}->{}", r.streams_initial, r.streams_final)
        };
        println!(
            "{:>14} {:>9} {:>12} {:>9.1}MB/s {:>8} {:>12}",
            r.mode,
            streams,
            fmt_secs(r.secs),
            r.mbps,
            r.losses,
            fmt_bytes(r.retransmit_bytes)
        );
    }
}

/// One `fig_repair_sources` row: a full shard repair under a source
/// policy while DC0's LAN is congested by background flows.
#[derive(Debug, Clone)]
pub struct RepairSourceRow {
    /// `home-dc` or `link-aware`.
    pub policy: &'static str,
    /// Distinct source DCs the repair actually pulled from.
    pub src_dcs: Vec<usize>,
    /// Metadata rows healed.
    pub healed: usize,
    /// Payload bytes re-replicated.
    pub bytes_moved: u64,
    /// Repair duration (data plane), virtual seconds.
    pub secs: f64,
}

/// Loss/load-aware replica sourcing under a congested home DC: shard 2
/// (DC2) misses `entries` rows homed in DC0 while DC0's LAN carries
/// four long-running background flows. `home-dc` pulls every payload
/// from DC0 anyway and shares the congested LAN; `link-aware` ranks
/// the live owner-chain DCs by [`crate::simnet::Network::path_load`]
/// and steers the repair through the idle DC1 replica instead. The
/// acceptance shape: link-aware sources exclude DC0 and the repair
/// completes strictly faster.
pub fn fig_repair_sources(entries: usize, entry_bytes: u64) -> Vec<RepairSourceRow> {
    use crate::metadata::replication::{repair_with_xfer_tuned, ReplicatedPlane, SourcePolicy};
    use crate::metadata::FileMeta;
    [SourcePolicy::HomeDc, SourcePolicy::LinkAware]
        .iter()
        .map(|&policy| {
            let mut env = Engine::new();
            let mut net = Network::build(&mut env, &NetConfig::paper_default(), 3);
            let dc_of_shard = [0usize, 1, 2]; // shard s hosted in DC s
            let mut plane = ReplicatedPlane::new(3, 2);
            plane.set_up(2, false);
            for i in 0..entries {
                plane.upsert(FileMeta {
                    path: format!("/exp/f{i}"),
                    dc: 0,
                    size: entry_bytes,
                    owner: "bench".into(),
                    mtime: 0.0,
                    sync: true,
                    namespace: "global".into(),
                });
            }
            plane.set_up(2, true);
            // congest DC0's LAN: four long-running flows plus two
            // registered bulk transfers, warmed into service by a tiny
            // drained send on DC1's LAN so the ranking sees them live
            for _ in 0..4 {
                env.start_flow(&[net.lans[0].res], 4 << 30, 0.0, 1.0);
            }
            net.begin_transfer(0, 0);
            net.begin_transfer(0, 0);
            let now = net.route(&mut env, 1, 1, 0.0, 64 << 10);
            let engine = XferEngine::new(XferConfig::default());
            let mut paths = PathStateTable::new();
            let rep = repair_with_xfer_tuned(
                &mut plane,
                2,
                &mut env,
                &mut net,
                &engine,
                &dc_of_shard,
                &mut FaultInjector::none(),
                now,
                policy,
                &mut paths,
            )
            .expect("repair");
            let mut src_dcs: Vec<usize> = rep.transfers.iter().map(|t| t.src_dc).collect();
            src_dcs.sort_unstable();
            src_dcs.dedup();
            RepairSourceRow {
                policy: match policy {
                    SourcePolicy::HomeDc => "home-dc",
                    SourcePolicy::LinkAware => "link-aware",
                },
                src_dcs,
                healed: rep.healed,
                bytes_moved: rep.bytes_moved,
                secs: rep.finished_at - now,
            }
        })
        .collect()
}

/// Print `fig_repair_sources` rows.
pub fn print_repair_sources(rows: &[RepairSourceRow]) {
    println!("\n== Fig repair-sources: shard repair with DC0's LAN congested ==");
    println!("{:>12} {:>8} {:>10} {:>12} {:>12}", "policy", "healed", "sources", "moved", "time");
    for r in rows {
        let srcs = r.src_dcs.iter().map(|d| format!("dc{d}")).collect::<Vec<_>>().join("+");
        println!(
            "{:>12} {:>8} {:>10} {:>12} {:>12}",
            r.policy,
            r.healed,
            srcs,
            fmt_bytes(r.bytes_moved),
            fmt_secs(r.secs)
        );
    }
    if let [home, aware] = rows {
        if aware.secs < home.secs {
            println!(
                "link-aware repair {:.1}% faster than home-dc under source congestion",
                (home.secs - aware.secs) / home.secs * 100.0
            );
        }
    }
}

/// Machine-readable `BENCH_xfer.json` payload: the lossless and the
/// congested stream sweeps, the adaptive-vs-fixed comparison and the
/// repair source-policy comparison side by side, so CI tracks the
/// striping plateau, the over-striping collapse *and* the autotuner's
/// acceptance bands per PR.
pub fn xfer_json(
    total: u64,
    plain: &[XferStreamRow],
    congested: &[XferCcRow],
    adaptive: &[XferAdaptiveRow],
    repair: &[RepairSourceRow],
) -> Json {
    use std::collections::BTreeMap;
    let plain_rows: Vec<Json> = plain
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("streams".to_string(), Json::Num(r.streams as f64));
            m.insert("secs".to_string(), Json::Num(r.secs));
            m.insert("mbps".to_string(), Json::Num(r.mbps));
            Json::Obj(m)
        })
        .collect();
    let cc_rows: Vec<Json> = congested
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("streams".to_string(), Json::Num(r.streams as f64));
            m.insert("secs".to_string(), Json::Num(r.secs));
            m.insert("mbps".to_string(), Json::Num(r.mbps));
            m.insert("losses".to_string(), Json::Num(r.losses as f64));
            m.insert("retransmit_bytes".to_string(), Json::Num(r.retransmit_bytes as f64));
            Json::Obj(m)
        })
        .collect();
    let adaptive_rows: Vec<Json> = adaptive
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("scenario".to_string(), Json::Str(r.scenario.to_string()));
            m.insert("mode".to_string(), Json::Str(r.mode.clone()));
            m.insert("streams_initial".to_string(), Json::Num(r.streams_initial as f64));
            m.insert("streams_final".to_string(), Json::Num(r.streams_final as f64));
            m.insert("secs".to_string(), Json::Num(r.secs));
            m.insert("mbps".to_string(), Json::Num(r.mbps));
            m.insert("losses".to_string(), Json::Num(r.losses as f64));
            m.insert("retransmit_bytes".to_string(), Json::Num(r.retransmit_bytes as f64));
            m.insert("widens".to_string(), Json::Num(r.widens as f64));
            m.insert("sheds".to_string(), Json::Num(r.sheds as f64));
            Json::Obj(m)
        })
        .collect();
    let repair_rows: Vec<Json> = repair
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("policy".to_string(), Json::Str(r.policy.to_string()));
            m.insert(
                "src_dcs".to_string(),
                Json::Arr(r.src_dcs.iter().map(|&d| Json::Num(d as f64)).collect()),
            );
            m.insert("healed".to_string(), Json::Num(r.healed as f64));
            m.insert("bytes_moved".to_string(), Json::Num(r.bytes_moved as f64));
            m.insert("secs".to_string(), Json::Num(r.secs));
            Json::Obj(m)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("xfer".to_string()));
    top.insert("total_bytes".to_string(), Json::Num(total as f64));
    top.insert("plain".to_string(), Json::Arr(plain_rows));
    top.insert("congested".to_string(), Json::Arr(cc_rows));
    top.insert("adaptive".to_string(), Json::Arr(adaptive_rows));
    top.insert("repair_sources".to_string(), Json::Arr(repair_rows));
    Json::Obj(top)
}

/// One `fig_engine_hotpath` row: raw event-engine throughput on a
/// saturating multi-flow drain.
#[derive(Debug, Clone)]
pub struct EngineHotpathRow {
    /// Concurrent transfers in the drain.
    pub transfers: usize,
    /// Heap events the engine processed (its own counter).
    pub events_processed: u64,
    /// Virtual seconds the drain covered.
    pub sim_seconds: f64,
    /// Wall-clock seconds the drain took.
    pub wall_clock_s: f64,
    /// Events processed per wall-clock second.
    pub events_per_sec: f64,
    /// Wall-clock seconds spent per simulated second.
    pub wall_clock_per_sim_second: f64,
}

/// The engine's self-reported hot-path throughput (the ROADMAP's
/// observability prerequisite to the hot-path work): drain `transfers`
/// concurrent congestion-managed transfers on the geo WAN — a
/// loss/retransmit/window-tick-heavy event mix — and report events/sec
/// and wall-clock-per-sim-second from [`Engine::events_processed`].
pub fn fig_engine_hotpath(transfers: usize, bytes: u64) -> EngineHotpathRow {
    let mut env = Engine::new();
    let mut net = Network::build(&mut env, &NetConfig::geo_default(), 2);
    let cfg = XferConfig {
        n_streams: 8,
        cc: crate::xfer::CongestionConfig::on(),
        ..XferConfig::default()
    };
    let reqs: Vec<TransferRequest> = (0..transfers)
        .map(|i| TransferRequest {
            id: i as u64,
            owner: format!("hp{i}"),
            src_dc: 0,
            dst_dc: 1,
            bytes,
            priority: Priority::Bulk,
            submitted_at: 0.0,
        })
        .collect();
    let (reports, wall_clock_s) =
        crate::util::timer::time_it(|| run_flows(&mut env, &mut net, &cfg, &reqs, false));
    assert_eq!(reports.len(), reqs.len(), "every hot-path transfer must complete");
    let sim_seconds = reports.iter().map(|r| r.finished_at).fold(0.0, f64::max);
    let events_processed = env.events_processed();
    let events_per_sec =
        if wall_clock_s > 0.0 { events_processed as f64 / wall_clock_s } else { 0.0 };
    let wall_clock_per_sim_second =
        if sim_seconds > 0.0 { wall_clock_s / sim_seconds } else { 0.0 };
    EngineHotpathRow {
        transfers,
        events_processed,
        sim_seconds,
        wall_clock_s,
        events_per_sec,
        wall_clock_per_sim_second,
    }
}

/// One flow-count sweep point: the same single-congested-link drain
/// timed under the incremental scheduler and the retained
/// full-recompute reference ([`SchedMode::FullRecompute`]), so the
/// superlinear blow-up of the old scheme — and the speedup of the new
/// one — is visible per scale.
#[derive(Debug, Clone)]
pub struct EngineSweepRow {
    /// Concurrent flows sharing the link.
    pub flows: usize,
    /// Drain repetitions folded into the timing (small scales repeat
    /// so the wall-clock rises above timer noise).
    pub rounds: usize,
    /// Live heap events across all rounds (identical in both modes —
    /// asserted, along with bit-identical finish times).
    pub events_processed: u64,
    /// Orphaned (lazily deleted) heap entries, incremental mode.
    pub events_orphaned: u64,
    /// Wall-clock seconds, incremental mode.
    pub wall_clock_s: f64,
    /// Live events per wall-clock second, incremental mode.
    pub events_per_sec: f64,
    /// Wall-clock seconds, full-recompute reference.
    pub ref_wall_clock_s: f64,
    /// Live events per wall-clock second, full-recompute reference.
    pub ref_events_per_sec: f64,
    /// Orphaned heap entries, full-recompute reference.
    pub ref_events_orphaned: u64,
    /// `ref_wall_clock_s / wall_clock_s` — the before/after speedup.
    pub speedup: f64,
}

/// One timed drain at a sweep point: `n` flows with skewed sizes,
/// weights and staggered arrivals on one shared link, repeated
/// `rounds` times on a fresh engine. Returns the first round's finish
/// bits plus summed live/orphaned event counts and the wall clock.
fn sweep_drain(n: usize, rounds: usize, mode: SchedMode) -> (Vec<u64>, u64, u64, f64) {
    let mut finishes: Vec<u64> = Vec::new();
    let mut events = 0u64;
    let mut orphans = 0u64;
    let ((), wall_clock_s) = crate::util::timer::time_it(|| {
        for _ in 0..rounds {
            let mut e = Engine::new();
            e.set_sched_mode(mode);
            let l = e.add_link("hot", 10e9, 1e-4);
            // skewed sizes + staggered arrivals: every join and every
            // completion reshuffles the fair shares, which is exactly
            // the wave the old scheme re-water-filled per flow
            let fs: Vec<_> = (0..n)
                .map(|i| {
                    let bytes = ((i as u64 % 29) + 1) << 18;
                    let w = [1.0, 2.0, 4.0][i % 3];
                    e.start_flow(&[l], bytes, i as f64 * 1e-5, w)
                })
                .collect();
            e.run_until_idle();
            events += e.events_processed();
            orphans += e.events_orphaned();
            if finishes.is_empty() {
                finishes = fs
                    .iter()
                    .map(|&f| e.flow_finish(f).expect("sweep flow must drain").to_bits())
                    .collect();
            }
        }
    });
    (finishes, events, orphans, wall_clock_s)
}

/// ISSUE 7 satellite: sweep concurrent-flow counts (4 / 64 / 1024) on
/// one congested link, timing each scale under both scheduling modes.
/// Asserts in passing that the two modes drain to bit-identical finish
/// times with equal live-event counts — the bench doubles as a cheap
/// end-to-end equivalence check.
pub fn fig_engine_flow_sweep() -> Vec<EngineSweepRow> {
    [4usize, 64, 1024]
        .iter()
        .map(|&n| {
            let rounds = (4096 / n).max(1);
            let (bits, ev, orph, wall) = sweep_drain(n, rounds, SchedMode::Incremental);
            let (ref_bits, ref_ev, ref_orph, ref_wall) =
                sweep_drain(n, rounds, SchedMode::FullRecompute);
            assert_eq!(bits, ref_bits, "sweep({n}): modes must drain to identical finish bits");
            assert_eq!(ev, ref_ev, "sweep({n}): live event counts must match across modes");
            let eps = |e: u64, w: f64| if w > 0.0 { e as f64 / w } else { 0.0 };
            EngineSweepRow {
                flows: n,
                rounds,
                events_processed: ev,
                events_orphaned: orph,
                wall_clock_s: wall,
                events_per_sec: eps(ev, wall),
                ref_wall_clock_s: ref_wall,
                ref_events_per_sec: eps(ref_ev, ref_wall),
                ref_events_orphaned: ref_orph,
                speedup: if wall > 0.0 { ref_wall / wall } else { 0.0 },
            }
        })
        .collect()
}

/// Print the flow-count sweep rows.
pub fn print_engine_sweep(rows: &[EngineSweepRow]) {
    println!("\n== Fig engine-sweep: incremental vs full-recompute scheduling ==");
    println!(
        "{:>6} {:>7} {:>12} {:>14} {:>14} {:>9}",
        "flows", "rounds", "live events", "inc events/s", "ref events/s", "speedup"
    );
    for r in rows {
        println!(
            "{:>6} {:>7} {:>12} {:>14.0} {:>14.0} {:>8.2}x",
            r.flows, r.rounds, r.events_processed, r.events_per_sec, r.ref_events_per_sec, r.speedup
        );
    }
}

/// Print the `fig_engine_hotpath` row.
pub fn print_engine(row: &EngineHotpathRow) {
    println!("\n== Fig engine-hotpath: event throughput on a congested drain ==");
    println!(
        "{} transfers: {} events over {} simulated ({} wall)",
        row.transfers,
        row.events_processed,
        fmt_secs(row.sim_seconds),
        fmt_secs(row.wall_clock_s)
    );
    println!(
        "{:.0} events/sec, {:.6} wall-clock seconds per simulated second",
        row.events_per_sec, row.wall_clock_per_sim_second
    );
}

/// Machine-readable `BENCH_engine.json` payload: the engine's
/// self-reported events/sec and wall-clock-per-sim-second (legacy
/// top-level keys, unchanged), plus one `sweep` row per flow-count
/// scale with the incremental-vs-full-recompute speedup. CI gates the
/// sweep rows (1024-flow floor, low-vs-high ratio, speedup >= 2x).
pub fn engine_json(row: &EngineHotpathRow, sweep: &[EngineSweepRow]) -> Json {
    use std::collections::BTreeMap;
    let mut m = BTreeMap::new();
    m.insert("bench".to_string(), Json::Str("engine".to_string()));
    m.insert("transfers".to_string(), Json::Num(row.transfers as f64));
    m.insert("events_processed".to_string(), Json::Num(row.events_processed as f64));
    m.insert("sim_seconds".to_string(), Json::Num(row.sim_seconds));
    m.insert("wall_clock_s".to_string(), Json::Num(row.wall_clock_s));
    m.insert("events_per_sec".to_string(), Json::Num(row.events_per_sec));
    m.insert(
        "wall_clock_per_sim_second".to_string(),
        Json::Num(row.wall_clock_per_sim_second),
    );
    let rows: Vec<Json> = sweep
        .iter()
        .map(|r| {
            let mut s = BTreeMap::new();
            s.insert("flows".to_string(), Json::Num(r.flows as f64));
            s.insert("rounds".to_string(), Json::Num(r.rounds as f64));
            s.insert("events_processed".to_string(), Json::Num(r.events_processed as f64));
            s.insert("events_orphaned".to_string(), Json::Num(r.events_orphaned as f64));
            s.insert("wall_clock_s".to_string(), Json::Num(r.wall_clock_s));
            s.insert("events_per_sec".to_string(), Json::Num(r.events_per_sec));
            s.insert("ref_wall_clock_s".to_string(), Json::Num(r.ref_wall_clock_s));
            s.insert("ref_events_per_sec".to_string(), Json::Num(r.ref_events_per_sec));
            s.insert("ref_events_orphaned".to_string(), Json::Num(r.ref_events_orphaned as f64));
            s.insert("speedup".to_string(), Json::Num(r.speedup));
            Json::Obj(s)
        })
        .collect();
    m.insert("sweep".to_string(), Json::Arr(rows));
    Json::Obj(m)
}

/// Machine-readable `BENCH_preempt.json` payload.
pub fn preempt_json(rows: &[PreemptRow]) -> Json {
    use std::collections::BTreeMap;
    let out: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("preempt".to_string(), Json::Bool(r.preempt));
            m.insert("interactive_p50_s".to_string(), Json::Num(r.interactive_p50_s));
            m.insert("interactive_p99_s".to_string(), Json::Num(r.interactive_p99_s));
            m.insert("interactive_mean_s".to_string(), Json::Num(r.interactive_mean_s));
            m.insert("bulk_makespan_s".to_string(), Json::Num(r.bulk_makespan_s));
            Json::Obj(m)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("preempt".to_string()));
    top.insert("rows".to_string(), Json::Arr(out));
    Json::Obj(top)
}

/// Print `fig_xfer_streams` rows.
pub fn print_xfer_streams(total: u64, rows: &[XferStreamRow]) {
    println!("\n== Fig xfer-streams: {} DC0->DC1, stream-count sweep ==", fmt_bytes(total));
    println!("{:>8} {:>12} {:>12}", "streams", "time", "goodput");
    for r in rows {
        println!("{:>8} {:>12} {:>9.1}MB/s", r.streams, fmt_secs(r.secs), r.mbps);
    }
    let floor = total as f64 / NetConfig::paper_default().wan_bw;
    println!("{:>8} {:>12} (link byte-serialization floor)", "wire", fmt_secs(floor));
}

/// Print `fig_xfer_mix` rows.
pub fn print_xfer_mix(rows: &[XferMixRow]) {
    println!("\n== Fig xfer-mix: concurrent collaborations on one WAN ==");
    if let Some(r) = rows.first() {
        println!("(peak concurrent WAN transfers: {})", r.wan_peak);
    }
    println!(
        "{:>12} {:>12} {:>10} {:>12} {:>12} {:>8}",
        "owner", "priority", "bytes", "finished", "goodput", "retried"
    );
    for r in rows {
        println!(
            "{:>12} {:>12} {:>10} {:>12} {:>9.1}MB/s {:>8}",
            r.owner,
            r.priority,
            fmt_bytes(r.bytes),
            fmt_secs(r.finished_s),
            r.mbps,
            r.retried
        );
    }
}

/// Pretty-print helpers shared by the bench binaries.
pub fn print_throughput(title: &str, xlabel: &str, rows: &[ThroughputRow]) {
    println!("\n== {title} ==");
    println!("{xlabel:>12} {:>12} {:>12} {:>12} {:>10}", "baseline", "scispace", "scispace-lw", "lw-gain");
    for r in rows {
        let x = if xlabel.contains("block") { fmt_bytes(r.x) } else { r.x.to_string() };
        println!(
            "{x:>12} {:>10.1}MB/s {:>10.1}MB/s {:>10.1}MB/s {:>+9.1}%",
            r.baseline, r.scispace, r.lw, r.lw_gain_pct()
        );
    }
}

/// Print Fig. 9a rows.
pub fn print_meu(rows: &[MeuRow]) {
    println!("\n== Fig 9a: MEU — zero-size file create + export ==");
    println!("{:>10} {:>14} {:>14} {:>14}", "files", "baseline", "scispace-lw", "lw+meu");
    for r in rows {
        println!(
            "{:>10} {:>14} {:>14} {:>14}",
            r.files,
            fmt_secs(r.baseline_s),
            fmt_secs(r.lw_s),
            fmt_secs(r.lw_meu_s)
        );
    }
}

/// Print Fig. 9b rows.
pub fn print_sds_modes(rows: &[SdsModeRow]) {
    println!("\n== Fig 9b: SDS extraction modes (4 collaborators) ==");
    println!("{:>8} {:>14} {:>14} {:>14} {:>18}", "attrs", "inline-sync", "inline-async", "lw-offline", "async/offline gain");
    for r in rows {
        let g_async = (r.inline_sync_s - r.inline_async_s) / r.inline_sync_s * 100.0;
        let g_off = (r.inline_sync_s - r.lw_offline_s) / r.inline_sync_s * 100.0;
        println!(
            "{:>8} {:>14} {:>14} {:>14} {:>8.0}% /{:>6.0}%",
            r.attrs,
            fmt_secs(r.inline_sync_s),
            fmt_secs(r.inline_async_s),
            fmt_secs(r.lw_offline_s),
            g_async,
            g_off
        );
    }
}

/// Print Table II rows.
pub fn print_table2(rows: &[QueryLatencyRow]) {
    println!("\n== Table II: query latency vs hit ratio ==");
    println!("{:>20} {:>9} {:>9} {:>9} {:>9} {:>9}", "attribute", "0%", "25%", "50%", "75%", "100%");
    for r in rows {
        let cells: Vec<String> = r.latencies.iter().map(|(_, l)| fmt_secs(*l)).collect();
        println!(
            "{:>20} {:>9} {:>9} {:>9} {:>9} {:>9}",
            r.attr, cells[0], cells[1], cells[2], cells[3], cells[4]
        );
    }
}

/// Print Fig. 9c rows.
pub fn print_end2end(rows: &[End2EndRow]) {
    println!("\n== Fig 9c: end-to-end H5Diff collaboration ==");
    println!("{:>8} {:>14} {:>14} {:>10}", "files", "baseline", "scispace", "speedup");
    for r in rows {
        println!(
            "{:>8} {:>14} {:>14} {:>9.2}x",
            r.files,
            fmt_secs(r.baseline_s),
            fmt_secs(r.scispace_s),
            r.baseline_s / r.scispace_s
        );
    }
}

/// One `fig_federation` row: a federation scenario at one site count,
/// cache tier on or off.
#[derive(Debug, Clone)]
pub struct FederationRow {
    /// Scenario name: `flash_crowd`, `straggler` or `outage`.
    pub scenario: &'static str,
    /// Sites in the federation.
    pub sites: usize,
    /// Cache tier on?
    pub cache: bool,
    /// Reads attempted.
    pub reads: usize,
    /// Reads that failed (outage scenario).
    pub failed: usize,
    /// Median time-to-first-byte across successful reads, seconds (the
    /// typical reader in the crowd — the CI-gated number).
    pub ttfb_p50_s: f64,
    /// Mean time-to-first-byte, seconds.
    pub ttfb_mean_s: f64,
    /// Mean whole-read completion time, seconds.
    pub read_mean_s: f64,
    /// `1 - origin_egress / delivered` over the run.
    pub offload_ratio: f64,
    /// Bytes the origins egressed (direct serves + cache fills).
    pub origin_bytes: u64,
    /// Cache hits across all regions.
    pub cache_hits: u64,
    /// Cache misses across all regions.
    pub cache_misses: u64,
    /// LRU evictions across all regions.
    pub cache_evicts: u64,
}

/// The hot dataset every reader in the crowd wants (well above the
/// bulk-transfer threshold, well below the per-region cache capacity).
const FED_HOT_BYTES: u64 = 32 << 20;
/// Cache sites per region in the bench federations.
const FED_REGION_SIZE: usize = 4;
/// Per-region cache capacity when the tier is on.
const FED_CACHE_CAP: u64 = 256 << 20;

fn federation_bed(sites: usize, cache: bool) -> Testbed {
    let cap = if cache { FED_CACHE_CAP } else { 0 };
    crate::federation::FederationSpec::tiered(sites, 1, FED_REGION_SIZE, cap).build()
}

struct FedReadSample {
    ttfb: f64,
    total: f64,
}

/// One crowd read; `None` when the read failed (dead origin).
/// TTFB for a bulk read is queueing + first-chunk delivery estimated
/// from the transfer report; sub-threshold/local reads fall back to the
/// whole-read time (no earlier byte is observable).
fn federation_read(tb: &mut Testbed, r: usize, path: &str) -> Option<FedReadSample> {
    let t0 = tb.now(r);
    let (_, rep) = tb.read_traced(r, path, 0, FED_HOT_BYTES, AccessMode::Scispace).ok()?;
    let total = tb.now(r) - t0;
    let ttfb = match rep {
        Some(rep) => {
            let chunks = rep.chunks.max(1) as f64;
            (rep.started_at - t0).max(0.0) + (rep.finished_at - rep.started_at) / chunks
        }
        None => total,
    };
    Some(FedReadSample { ttfb, total })
}

fn federation_row(
    scenario: &'static str,
    sites: usize,
    cache: bool,
    tb: &Testbed,
    samples: &[FedReadSample],
    failed: usize,
) -> FederationRow {
    let fed = tb.federation.as_ref().expect("federated bed");
    let agg = fed.cache_totals();
    let mut ttfbs: Vec<f64> = samples.iter().map(|s| s.ttfb).collect();
    ttfbs.sort_by(|a, b| a.total_cmp(b));
    let mean = |v: &[f64]| if v.is_empty() { 0.0 } else { v.iter().sum::<f64>() / v.len() as f64 };
    let totals: Vec<f64> = samples.iter().map(|s| s.total).collect();
    FederationRow {
        scenario,
        sites,
        cache,
        reads: samples.len() + failed,
        failed,
        ttfb_p50_s: percentile(&ttfbs, 0.5),
        ttfb_mean_s: mean(&ttfbs),
        read_mean_s: mean(&totals),
        offload_ratio: fed.offload_ratio(),
        origin_bytes: fed.origin_egress_bytes,
        cache_hits: agg.hits,
        cache_misses: agg.misses,
        cache_evicts: agg.evicts,
    }
}

/// Stand up a federation with the hot dataset written at the origin and
/// one reader registered per cache site (site order — so each region's
/// cache host reads first and fills for its siblings).
fn federation_crowd(sites: usize, cache: bool) -> (Testbed, Vec<usize>) {
    let mut tb = federation_bed(sites, cache);
    let writer = tb.register("origin-writer", 0);
    tb.write(writer, "/fed/hot.dat", 0, FED_HOT_BYTES, None, AccessMode::Scispace)
        .expect("seed write");
    let readers: Vec<usize> = (1..sites).map(|d| tb.register(&format!("crowd{d}"), d)).collect();
    (tb, readers)
}

fn federation_flash_crowd(sites: usize, cache: bool) -> FederationRow {
    let (mut tb, readers) = federation_crowd(sites, cache);
    let mut samples = Vec::new();
    let mut failed = 0;
    for r in readers {
        match federation_read(&mut tb, r, "/fed/hot.dat") {
            Some(s) => samples.push(s),
            None => failed += 1,
        }
    }
    federation_row("flash_crowd", sites, cache, &tb, &samples, failed)
}

/// Flash crowd with region 0's aggregation link throttled to a tenth of
/// its class bandwidth before any reads start (re-provisioning requires
/// an idle link).
fn federation_straggler(sites: usize, cache: bool) -> FederationRow {
    let (mut tb, readers) = federation_crowd(sites, cache);
    if let Some(l) = tb.net.regionals.first() {
        let res = l.res;
        tb.env.set_link_bw(res, 2.5e8);
    }
    let mut samples = Vec::new();
    let mut failed = 0;
    for r in readers {
        match federation_read(&mut tb, r, "/fed/hot.dat") {
            Some(s) => samples.push(s),
            None => failed += 1,
        }
    }
    federation_row("straggler", sites, cache, &tb, &samples, failed)
}

/// Flash crowd with the origin taken down after the first half of the
/// crowd has read: warmed regions keep serving from cache, cold regions
/// fail (with the tier off, *every* remaining read fails).
fn federation_outage(sites: usize, cache: bool) -> FederationRow {
    let (mut tb, readers) = federation_crowd(sites, cache);
    let warm = readers.len() / 2;
    let mut samples = Vec::new();
    let mut failed = 0;
    for (i, r) in readers.into_iter().enumerate() {
        if i == warm {
            tb.set_site_down(0, true);
        }
        match federation_read(&mut tb, r, "/fed/hot.dat") {
            Some(s) => samples.push(s),
            None => failed += 1,
        }
    }
    federation_row("outage", sites, cache, &tb, &samples, failed)
}

/// The federation figure: flash-crowd / straggler-link / origin-outage
/// scenarios at each site count, cache tier on vs off. The cache-on
/// flash-crowd rows are the CI-gated ones: origin offload ratio > 0.5
/// at 48 sites, and median TTFB strictly below the cache-off row's.
pub fn fig_federation(site_counts: &[usize]) -> Vec<FederationRow> {
    let mut rows = Vec::new();
    for &sites in site_counts {
        for cache in [true, false] {
            rows.push(federation_flash_crowd(sites, cache));
            rows.push(federation_straggler(sites, cache));
            rows.push(federation_outage(sites, cache));
        }
    }
    rows
}

/// Print `fig_federation` rows.
pub fn print_federation(rows: &[FederationRow]) {
    println!("\n== Fig federation: flash crowd on {} across N sites ==", fmt_bytes(FED_HOT_BYTES));
    println!(
        "{:>12} {:>6} {:>6} {:>6} {:>7} {:>11} {:>11} {:>9} {:>6} {:>6} {:>6}",
        "scenario", "sites", "cache", "reads", "failed", "ttfb p50", "read mean", "offload", "hit",
        "miss", "evict"
    );
    for r in rows {
        println!(
            "{:>12} {:>6} {:>6} {:>6} {:>7} {:>11} {:>11} {:>8.1}% {:>6} {:>6} {:>6}",
            r.scenario,
            r.sites,
            if r.cache { "on" } else { "off" },
            r.reads,
            r.failed,
            fmt_secs(r.ttfb_p50_s),
            fmt_secs(r.read_mean_s),
            r.offload_ratio * 100.0,
            r.cache_hits,
            r.cache_misses,
            r.cache_evicts
        );
    }
}

/// Machine-readable `BENCH_federation.json` payload: rows grouped by
/// scenario.
pub fn federation_json(rows: &[FederationRow]) -> Json {
    use std::collections::BTreeMap;
    let row_json = |r: &FederationRow| {
        let mut m = BTreeMap::new();
        m.insert("sites".to_string(), Json::Num(r.sites as f64));
        m.insert("cache".to_string(), Json::Bool(r.cache));
        m.insert("reads".to_string(), Json::Num(r.reads as f64));
        m.insert("failed".to_string(), Json::Num(r.failed as f64));
        m.insert("ttfb_p50_s".to_string(), Json::Num(r.ttfb_p50_s));
        m.insert("ttfb_mean_s".to_string(), Json::Num(r.ttfb_mean_s));
        m.insert("read_mean_s".to_string(), Json::Num(r.read_mean_s));
        m.insert("offload_ratio".to_string(), Json::Num(r.offload_ratio));
        m.insert("origin_bytes".to_string(), Json::Num(r.origin_bytes as f64));
        m.insert("cache_hits".to_string(), Json::Num(r.cache_hits as f64));
        m.insert("cache_misses".to_string(), Json::Num(r.cache_misses as f64));
        m.insert("cache_evicts".to_string(), Json::Num(r.cache_evicts as f64));
        Json::Obj(m)
    };
    let group = |name: &str| -> Json {
        Json::Arr(rows.iter().filter(|r| r.scenario == name).map(row_json).collect())
    };
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("federation".to_string()));
    top.insert("hot_bytes".to_string(), Json::Num(FED_HOT_BYTES as f64));
    top.insert("flash_crowd".to_string(), group("flash_crowd"));
    top.insert("straggler".to_string(), group("straggler"));
    top.insert("outage".to_string(), group("outage"));
    Json::Obj(top)
}

/// `scispace bench scale` ramp parameters.
#[derive(Debug, Clone)]
pub struct ScaleBenchConfig {
    /// Reading collaborators (split across the two DCs).
    pub collabs: usize,
    /// Pre-populated heavy-tailed files reads draw from.
    pub files: usize,
    /// First ramp step's offered rate, requests/s.
    pub initial_rps: f64,
    /// Ramp ceiling, requests/s.
    pub max_rps: f64,
    /// Offered-rate increment per step.
    pub step_rps: f64,
    /// Arrival-window length per step, virtual seconds.
    pub step_secs: f64,
    /// The SLO: a step whose p99 total latency exceeds this violates.
    pub slo_p99_s: f64,
    /// Master seed (bed population + arrival draws).
    pub seed: u64,
}

impl Default for ScaleBenchConfig {
    fn default() -> Self {
        ScaleBenchConfig {
            collabs: 1200,
            files: 600,
            initial_rps: 50.0,
            max_rps: 600.0,
            step_rps: 50.0,
            step_secs: 15.0,
            slo_p99_s: 2.0,
            seed: 2601,
        }
    }
}

/// One ramp step: offered rate vs the measured latency split.
#[derive(Debug, Clone)]
pub struct ScaleStepRow {
    /// Offered Poisson rate, requests/s.
    pub rps: f64,
    /// Ops scheduled in the step's arrival window.
    pub offered: usize,
    /// Ops that completed successfully.
    pub completed: usize,
    /// Ops that failed (should be 0 on this bed).
    pub failed: usize,
    /// Median arrival → completion latency (`None`: no completions).
    pub p50_total_s: Option<f64>,
    /// p99 arrival → completion latency — the SLO subject.
    pub p99_total_s: Option<f64>,
    /// Median queueing delay (arrival → admission).
    pub p50_queue_s: Option<f64>,
    /// p99 queueing delay.
    pub p99_queue_s: Option<f64>,
    /// p99 service latency (admission → completion).
    pub p99_service_s: Option<f64>,
    /// Completions per second of drain (first arrival to last finish).
    pub achieved_rps: f64,
    /// SLO verdict: `None` when the step measured nothing (empty bins
    /// are explicit — they never vacuously pass).
    pub slo_ok: Option<bool>,
}

/// The whole ramp: per-step curve plus the headline number.
#[derive(Debug, Clone)]
pub struct ScaleResult {
    /// The parameters that produced this curve.
    pub config: ScaleBenchConfig,
    /// One row per ramp step, in ramp order.
    pub steps: Vec<ScaleStepRow>,
    /// Highest offered rate whose p99 stayed inside the SLO (0 when
    /// even the first step violated).
    pub max_sustainable_rps: f64,
}

/// Build the scale bed: the bench cache scaling on a geo-regime WAN
/// (the shared bottleneck the ramp is meant to saturate), `collabs`
/// readers split across both DCs, and the heavy-tailed corpus written
/// so roughly half of all uniform reads cross the WAN.
fn scale_bed(wl: &workload::ScaleConfig) -> Testbed {
    let mut cfg = bench_config();
    cfg.net.wan_bw = 200e6;
    cfg.net.wan_latency_s = 5e-3;
    let mut tb = Testbed::build(cfg);
    for i in 0..wl.n_collabs {
        tb.register(&format!("r{i}"), i % 2);
    }
    let pubs: Vec<usize> = (0..2).map(|d| tb.register(&format!("pub{d}"), d)).collect();
    for (i, &sz) in workload::scale_file_sizes(wl).iter().enumerate() {
        tb.session(pubs[i % 2])
            .write(&workload::scale_path(i))
            .len(sz)
            .submit()
            .expect("scale populate");
    }
    tb.quiesce();
    tb
}

/// The saturation ramp (IC-scalability-suite protocol): offer an
/// open-loop Poisson workload at `initial_rps`, measure the p50/p99
/// latency split through `obs::metrics`, and raise the rate by
/// `step_rps` per step until the p99 total latency breaks the SLO (or
/// the ramp ceiling is reached). Each step runs on a fresh bed from
/// the same seed, so the curve is a pure function of the config.
pub fn fig_scale(cfg: &ScaleBenchConfig) -> ScaleResult {
    let mut steps = Vec::new();
    let mut max_sustainable = 0.0f64;
    let mut rps = cfg.initial_rps;
    while rps <= cfg.max_rps + 1e-9 {
        let wl = workload::ScaleConfig {
            n_collabs: cfg.collabs,
            n_files: cfg.files,
            duration_s: cfg.step_secs,
            process: workload::ArrivalProcess::Poisson { rps },
            seed: cfg.seed,
            ..workload::ScaleConfig::default()
        };
        let mut tb = scale_bed(&wl);
        let start = (0..tb.collabs.len()).map(|c| tb.now(c)).fold(0.0, f64::max);
        let ops = workload::scale_ops(&wl, start);
        let offered = ops.len();
        let outcomes = tb.run_batch_open(ops);

        // the latency split flows through the metrics registry; a step
        // with no completions leaves empty histograms whose percentiles
        // are `None` — skipped by the SLO check, never a free pass
        let mut m = Metrics::new();
        let mut failed = 0usize;
        let mut last_fin = start;
        for o in &outcomes {
            if o.result.is_ok() {
                m.observe("scale.total_s", o.total_s());
                m.observe("scale.queue_s", o.queueing_s());
                m.observe("scale.service_s", o.service_s());
                last_fin = last_fin.max(o.result.finished_at());
            } else {
                failed += 1;
            }
        }
        let completed = offered - failed;
        let p = |name: &str, q: f64| m.histogram(name).and_then(|h| h.percentile(q));
        let p99_total = p("scale.total_s", 99.0);
        let row = ScaleStepRow {
            rps,
            offered,
            completed,
            failed,
            p50_total_s: p("scale.total_s", 50.0),
            p99_total_s: p99_total,
            p50_queue_s: p("scale.queue_s", 50.0),
            p99_queue_s: p("scale.queue_s", 99.0),
            p99_service_s: p("scale.service_s", 99.0),
            achieved_rps: if last_fin > start {
                completed as f64 / (last_fin - start)
            } else {
                0.0
            },
            slo_ok: p99_total.map(|v| v <= cfg.slo_p99_s),
        };
        let violated = row.slo_ok == Some(false);
        if row.slo_ok == Some(true) {
            max_sustainable = rps;
        }
        steps.push(row);
        if violated {
            break;
        }
        rps += cfg.step_rps;
    }
    ScaleResult { config: cfg.clone(), steps, max_sustainable_rps: max_sustainable }
}

fn fmt_opt_secs(v: Option<f64>) -> String {
    v.map(fmt_secs).unwrap_or_else(|| "-".to_string())
}

/// Print the ramp curve and the headline number.
pub fn print_scale(res: &ScaleResult) {
    let cfg = &res.config;
    println!(
        "\n== Bench scale: open-loop saturation ramp, {} collaborators, {} files, SLO p99 <= {} ==",
        cfg.collabs,
        cfg.files,
        fmt_secs(cfg.slo_p99_s)
    );
    println!(
        "{:>8} {:>8} {:>6} {:>11} {:>11} {:>11} {:>11} {:>9} {:>5}",
        "rps", "offered", "fail", "total-p50", "total-p99", "queue-p99", "serv-p99", "ach-rps",
        "slo"
    );
    for r in &res.steps {
        println!(
            "{:>8.0} {:>8} {:>6} {:>11} {:>11} {:>11} {:>11} {:>9.1} {:>5}",
            r.rps,
            r.offered,
            r.failed,
            fmt_opt_secs(r.p50_total_s),
            fmt_opt_secs(r.p99_total_s),
            fmt_opt_secs(r.p99_queue_s),
            fmt_opt_secs(r.p99_service_s),
            r.achieved_rps,
            match r.slo_ok {
                Some(true) => "ok",
                Some(false) => "VIOL",
                None => "-",
            }
        );
    }
    println!("max sustainable throughput: {:.0} rps", res.max_sustainable_rps);
}

/// Machine-readable `BENCH_scale.json` payload: the full rate/latency
/// curve plus `max_sustainable_rps`, for the CI trend gate.
pub fn scale_json(res: &ScaleResult) -> Json {
    use std::collections::BTreeMap;
    let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
    let rows: Vec<Json> = res
        .steps
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("rps".to_string(), Json::Num(r.rps));
            m.insert("offered".to_string(), Json::Num(r.offered as f64));
            m.insert("completed".to_string(), Json::Num(r.completed as f64));
            m.insert("failed".to_string(), Json::Num(r.failed as f64));
            m.insert("p50_total_s".to_string(), opt(r.p50_total_s));
            m.insert("p99_total_s".to_string(), opt(r.p99_total_s));
            m.insert("p50_queue_s".to_string(), opt(r.p50_queue_s));
            m.insert("p99_queue_s".to_string(), opt(r.p99_queue_s));
            m.insert("p99_service_s".to_string(), opt(r.p99_service_s));
            m.insert("achieved_rps".to_string(), Json::Num(r.achieved_rps));
            m.insert("slo_ok".to_string(), r.slo_ok.map(Json::Bool).unwrap_or(Json::Null));
            Json::Obj(m)
        })
        .collect();
    let cfg = &res.config;
    let mut c = BTreeMap::new();
    c.insert("collabs".to_string(), Json::Num(cfg.collabs as f64));
    c.insert("files".to_string(), Json::Num(cfg.files as f64));
    c.insert("initial_rps".to_string(), Json::Num(cfg.initial_rps));
    c.insert("max_rps".to_string(), Json::Num(cfg.max_rps));
    c.insert("step_rps".to_string(), Json::Num(cfg.step_rps));
    c.insert("step_secs".to_string(), Json::Num(cfg.step_secs));
    c.insert("slo_p99_s".to_string(), Json::Num(cfg.slo_p99_s));
    c.insert("seed".to_string(), Json::Num(cfg.seed as f64));
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("scale".to_string()));
    top.insert("config".to_string(), Json::Obj(c));
    top.insert("steps".to_string(), Json::Arr(rows));
    top.insert("max_sustainable_rps".to_string(), Json::Num(res.max_sustainable_rps));
    Json::Obj(top)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig_scale_tiny_ramp_is_deterministic_and_accounts_queueing() {
        let cfg = ScaleBenchConfig {
            collabs: 40,
            files: 30,
            initial_rps: 20.0,
            max_rps: 40.0,
            step_rps: 20.0,
            step_secs: 3.0,
            slo_p99_s: 5.0,
            seed: 7,
        };
        let a = fig_scale(&cfg);
        let b = fig_scale(&cfg);
        assert_eq!(
            scale_json(&a).to_string(),
            scale_json(&b).to_string(),
            "same seed must reproduce the curve byte-for-byte"
        );
        assert!(!a.steps.is_empty());
        let s0 = &a.steps[0];
        assert!(s0.offered > 0 && s0.failed == 0, "{s0:?}");
        // total-latency samples dominate service samples pointwise
        // (total = queueing + service), so every percentile does too
        assert!(s0.p99_total_s.unwrap() + 1e-12 >= s0.p99_service_s.unwrap(), "{s0:?}");
        assert!(s0.p99_queue_s.unwrap() >= 0.0, "{s0:?}");
    }

    #[test]
    fn fig7_small_scale_shape() {
        let rows = fig7(IorOp::Write, &[4 << 10, 512 << 10], 24 << 20);
        // LW wins at 4 KB by a lot, converges at 512 KB
        assert!(rows[0].lw_gain_pct() > 25.0, "4KB gain {}", rows[0].lw_gain_pct());
        assert!(rows[1].lw_gain_pct() < rows[0].lw_gain_pct(), "gap must shrink with block size");
    }

    #[test]
    fn fig9a_small_scale_shape() {
        let rows = fig9a(&[500]);
        let r = &rows[0];
        assert!(r.baseline_s > r.lw_meu_s, "baseline {} must exceed lw+meu {}", r.baseline_s, r.lw_meu_s);
        assert!(r.lw_meu_s > r.lw_s, "meu adds cost over raw LW");
    }

    #[test]
    fn fig9b_small_scale_shape() {
        let rows = fig9b(&[5, 20], 10);
        for r in &rows {
            assert!(r.inline_async_s < r.inline_sync_s);
            assert!(r.lw_offline_s < r.inline_sync_s);
        }
        // more attributes widen the sync/async gap (paper: 12% -> 56%)
        let gap = |r: &SdsModeRow| (r.inline_sync_s - r.inline_async_s) / r.inline_sync_s;
        assert!(gap(&rows[1]) > gap(&rows[0]));
    }

    #[test]
    fn fig9c_small_scale_shape() {
        let rows = fig9c(&[8], None);
        assert!(rows[0].baseline_s > rows[0].scispace_s, "search+migrate must lose");
    }

    #[test]
    fn fig_xfer_streams_shape() {
        // Acceptance (a): strictly decreasing, then plateau at the floor.
        let rows = fig_xfer_streams(128 << 20, &[1, 2, 4, 8, 32]);
        assert!(rows[0].secs > rows[1].secs, "{rows:?}");
        assert!(rows[1].secs > rows[2].secs, "{rows:?}");
        assert!(rows[2].secs > rows[3].secs, "{rows:?}");
        let early = rows[0].secs - rows[3].secs;
        let late = (rows[3].secs - rows[4].secs).max(0.0);
        assert!(late < early * 0.1, "plateau expected: {rows:?}");
        let floor = (128u64 << 20) as f64 / NetConfig::paper_default().wan_bw;
        assert!(rows[4].secs >= floor);
    }

    #[test]
    fn fig_xfer_streams_cc_shows_over_striping_collapse() {
        // Tentpole acceptance: with congestion enabled the sweep is
        // non-monotonic — throughput peaks at an intermediate stream
        // count and degrades >= 10% past it — while the lossless sweep
        // (fig_xfer_streams_shape above) keeps its plateau.
        let counts = [1usize, 2, 4, 8, 16, 32, 64];
        let rows = fig_xfer_streams_cc(512 << 20, &counts);
        let peak = rows
            .iter()
            .cloned()
            .reduce(|a, b| if b.mbps > a.mbps { b } else { a })
            .expect("rows");
        let last = rows.last().expect("rows");
        assert!(peak.streams > 1 && peak.streams < 64, "the peak must be interior: {rows:?}");
        assert!(rows[0].mbps < peak.mbps * 0.8, "few streams must be window-limited: {rows:?}");
        assert!(
            last.mbps <= peak.mbps * 0.90,
            "over-striping must collapse >= 10% past the peak: {rows:?}"
        );
        assert!(last.losses > 0, "the collapse must be loss-driven: {rows:?}");
        assert!(last.retransmit_bytes > 0);
        // below saturation the window ceiling, not loss, is the limit
        assert_eq!(rows[0].losses, 0, "a lone window-limited stream never overloads: {rows:?}");
    }

    #[test]
    fn bench_json_payloads_round_trip() {
        let plain = fig_xfer_streams(32 << 20, &[1, 4]);
        let cc = fig_xfer_streams_cc(32 << 20, &[1, 4]);
        let adaptive = fig_xfer_adaptive(32 << 20, &[4]);
        let repair = fig_repair_sources(3, 8 << 20);
        let j = xfer_json(32 << 20, &plain, &cc, &adaptive, &repair);
        let parsed = crate::util::json::Json::parse(&j.to_string()).expect("valid json");
        assert_eq!(parsed.get("bench").and_then(|b| b.as_str()), Some("xfer"));
        assert_eq!(parsed.get("plain").and_then(|p| p.as_arr()).map(|a| a.len()), Some(2));
        assert_eq!(parsed.get("congested").and_then(|p| p.as_arr()).map(|a| a.len()), Some(2));
        // 3 scenarios x (1 fixed + adaptive-cold + adaptive)
        assert_eq!(parsed.get("adaptive").and_then(|p| p.as_arr()).map(|a| a.len()), Some(9));
        assert_eq!(
            parsed.get("repair_sources").and_then(|p| p.as_arr()).map(|a| a.len()),
            Some(2)
        );
        let rows = fig_preempt(4, 8 << 20, 2, 64 << 20);
        let j = preempt_json(&rows);
        let parsed = crate::util::json::Json::parse(&j.to_string()).expect("valid json");
        assert_eq!(parsed.get("rows").and_then(|p| p.as_arr()).map(|a| a.len()), Some(2));
    }

    #[test]
    fn fig_xfer_mix_interactive_wins() {
        let rows = fig_xfer_mix(64 << 20);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows.first().unwrap().wan_peak, 4, "mix must share the WAN concurrently");
        let finish = |owner: &str| {
            rows.iter().find(|r| r.owner == owner).map(|r| r.finished_s).unwrap()
        };
        assert!(
            finish("analyst") < finish("climate").min(finish("genomics")),
            "interactive must beat bulk: {rows:?}"
        );
    }

    #[test]
    fn fig_preempt_lowers_interactive_tail() {
        // Tentpole acceptance: Interactive p99 strictly lower with
        // preemption than without, under Bulk background load.
        let rows = fig_preempt(8, 32 << 20, 3, 512 << 20);
        let off = rows.iter().find(|r| !r.preempt).expect("off row");
        let on = rows.iter().find(|r| r.preempt).expect("on row");
        assert!(
            on.interactive_p99_s < off.interactive_p99_s,
            "preemption must cut the tail: on={} off={}",
            on.interactive_p99_s,
            off.interactive_p99_s
        );
        assert!(
            on.interactive_p50_s <= off.interactive_p50_s,
            "the median must not regress: on={} off={}",
            on.interactive_p50_s,
            off.interactive_p50_s
        );
        assert!(
            on.bulk_makespan_s >= off.bulk_makespan_s,
            "the win is paid by bulk, not conjured: on={} off={}",
            on.bulk_makespan_s,
            off.bulk_makespan_s
        );
    }

    #[test]
    fn fig_collab_concurrency_latency_grows_with_contention() {
        // run_batch acceptance at bench scale: more concurrent
        // collaborators on the shared WAN => higher per-op latency
        // (processor sharing), without starving anyone.
        let rows = fig_collab_concurrency(&[1, 4], 2, 16 << 20);
        assert_eq!(rows.len(), 2);
        let (one, four) = (&rows[0], &rows[1]);
        assert!(one.p50_s > 0.0 && four.p50_s > 0.0);
        assert!(
            four.p50_s > one.p50_s * 1.5,
            "4 collaborators sharing the WAN must slow each op: 1={} 4={}",
            one.p50_s,
            four.p50_s
        );
        for r in &rows {
            assert!(r.p99_s >= r.p50_s, "{r:?}");
            assert!(r.makespan_s >= r.p99_s, "{r:?}");
        }
        let asym = fig_collab_asymmetric(64 << 20, 1 << 20);
        assert!(
            (0.99..1.01).contains(&asym.stall_ratio()),
            "unrelated bulk must not stall the small read: {asym:?}"
        );
        assert!(asym.bulk_s > asym.read_concurrent_s, "{asym:?}");
        let j = collab_json(&rows, &asym);
        let parsed = crate::util::json::Json::parse(&j.to_string()).expect("valid json");
        assert_eq!(parsed.get("bench").and_then(|b| b.as_str()), Some("collab"));
        assert_eq!(parsed.get("rows").and_then(|p| p.as_arr()).map(|a| a.len()), Some(2));
        assert!(
            parsed.get("asymmetric").is_some(),
            "the asymmetric scenario must be in the payload: {parsed:?}"
        );
    }

    #[test]
    fn fig_engine_hotpath_reports_positive_throughput() {
        let row = fig_engine_hotpath(4, 16 << 20);
        assert!(row.events_processed > 0, "{row:?}");
        assert!(row.sim_seconds > 0.0, "{row:?}");
        assert!(row.events_per_sec > 0.0, "{row:?}");
        assert!(row.wall_clock_per_sim_second > 0.0, "{row:?}");
        // a small sweep (the bench binary runs the full 4/64/1024 one)
        let sweep: Vec<EngineSweepRow> = [4usize, 16]
            .iter()
            .map(|&n| {
                let (bits, ev, orph, wall) = sweep_drain(n, 8, SchedMode::Incremental);
                let (ref_bits, ref_ev, ref_orph, ref_wall) =
                    sweep_drain(n, 8, SchedMode::FullRecompute);
                assert_eq!(bits, ref_bits, "sweep({n}): finish bits must match across modes");
                assert_eq!(ev, ref_ev, "sweep({n}): live event counts must match across modes");
                EngineSweepRow {
                    flows: n,
                    rounds: 8,
                    events_processed: ev,
                    events_orphaned: orph,
                    wall_clock_s: wall,
                    events_per_sec: if wall > 0.0 { ev as f64 / wall } else { 0.0 },
                    ref_wall_clock_s: ref_wall,
                    ref_events_per_sec: if ref_wall > 0.0 { ref_ev as f64 / ref_wall } else { 0.0 },
                    ref_events_orphaned: ref_orph,
                    speedup: if wall > 0.0 { ref_wall / wall } else { 0.0 },
                }
            })
            .collect();
        assert!(sweep.iter().all(|r| r.events_processed > 0), "{sweep:?}");
        let j = engine_json(&row, &sweep);
        let parsed = crate::util::json::Json::parse(&j.to_string()).expect("valid json");
        assert_eq!(parsed.get("bench").and_then(|b| b.as_str()), Some("engine"));
        assert!(
            parsed.get("events_per_sec").and_then(Json::as_f64).unwrap_or(0.0) > 0.0,
            "{parsed:?}"
        );
        let rows = parsed.get("sweep").and_then(Json::as_arr).expect("sweep rows");
        assert_eq!(rows.len(), 2, "{parsed:?}");
        assert!(
            rows.iter()
                .all(|r| r.get("events_per_sec").and_then(Json::as_f64).unwrap_or(0.0) > 0.0),
            "{parsed:?}"
        );
    }

    #[test]
    fn fig_federation_small_scale_shape() {
        let rows = fig_federation(&[4]);
        assert_eq!(rows.len(), 6, "{rows:?}");
        let find = |scenario: &str, cache: bool| {
            rows.iter()
                .find(|r| r.scenario == scenario && r.cache == cache)
                .unwrap_or_else(|| panic!("no {scenario}/cache={cache} row"))
        };
        let fc_on = find("flash_crowd", true);
        let fc_off = find("flash_crowd", false);
        assert_eq!(fc_on.reads, 3);
        assert_eq!(fc_on.failed, 0);
        // 3 cache-site readers, one region: 1 fill + 2 hits
        assert_eq!(fc_on.cache_misses, 1, "{fc_on:?}");
        assert_eq!(fc_on.cache_hits, 2, "{fc_on:?}");
        assert!(fc_on.offload_ratio > 0.5, "{fc_on:?}");
        assert!(fc_off.offload_ratio.abs() < 1e-12, "{fc_off:?}");
        assert!(fc_on.ttfb_p50_s < fc_off.ttfb_p50_s, "{fc_on:?} vs {fc_off:?}");
        assert!(fc_on.origin_bytes < fc_off.origin_bytes, "{fc_on:?} vs {fc_off:?}");
        // outage: the cache tier keeps warmed regions alive, the
        // cache-off bed loses every post-outage read
        let out_on = find("outage", true);
        let out_off = find("outage", false);
        assert!(out_on.failed < out_off.failed, "{out_on:?} vs {out_off:?}");
        assert_eq!(out_off.failed, 2, "{out_off:?}");
        let j = federation_json(&rows);
        let parsed = Json::parse(&j.to_string()).expect("valid json");
        assert_eq!(parsed.get("bench").and_then(|b| b.as_str()), Some("federation"));
        for key in ["flash_crowd", "straggler", "outage"] {
            let n = parsed.get(key).and_then(Json::as_arr).map(|a| a.len());
            assert_eq!(n, Some(2), "{key}: {parsed:?}");
        }
    }

    #[test]
    fn table2_latency_monotone_in_hit_ratio() {
        let rows = table2(400, 8);
        for r in &rows {
            let l0 = r.latencies[0].1;
            let l100 = r.latencies[4].1;
            assert!(l100 > l0, "{}: 100% {} must exceed 0% {}", r.attr, l100, l0);
        }
    }
}
