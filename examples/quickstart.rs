//! Quickstart: build a two-data-center collaboration, share data through
//! the workspace, publish local writes with the MEU, and read across
//! sites — all through the typed Session API.
//!
//! Run: `cargo run --release --example quickstart`

use scispace::api::ScispaceError;
use scispace::meu;
use scispace::namespace::Scope;
use scispace::workspace::{AccessMode, Testbed};

fn main() -> anyhow::Result<()> {
    // Two data centers, two DTNs each (the paper's Table I testbed).
    let mut tb = Testbed::paper_default();
    let alice = tb.register("alice", 0); // scientist at DC 0 (e.g. OLCF)
    let bob = tb.register("bob", 1); // collaborator at DC 1 (e.g. NERSC)

    // A private scratch namespace for alice, a global collab namespace.
    tb.ns.define("alice-scratch", "alice", "/home/alice", Scope::Local)?;
    tb.ns.define("climate", "alice", "/collab/climate", Scope::Global)?;

    // 1. Workspace write: immediately visible to every collaborator.
    let mut sess = tb.session(alice);
    sess.write("/collab/climate/run42.out").data(b"sim-output!").submit()?;
    println!("alice wrote run42.out through scifs (sync=true on write)");

    // 2. Native (LW) writes: fast local path, not yet published.
    sess.write("/home/alice/notes.txt").data(b"secret").mode(AccessMode::ScispaceLw).submit()?;
    sess.write("/collab/climate/raw.dat").data(b"raw-data").mode(AccessMode::ScispaceLw).submit()?;
    let bob_view: Vec<String> = tb
        .session(bob)
        .ls("/")
        .submit()?
        .entries()?
        .into_iter()
        .map(|m| m.path)
        .collect();
    println!("alice wrote 2 files natively (LW) — bob sees: {bob_view:?}");

    // 3. MEU export publishes the local writes' metadata (git-push-like).
    let rep = meu::export(&mut tb, alice, "/", None)?;
    println!("alice ran MEU: {} files exported in {} batched RPC(s)", rep.exported, rep.rpcs);

    // 4. Bob's view: global namespace visible, alice's Local scope hidden
    //    — and the denial is a *typed* error, not a string.
    let view: Vec<String> = tb
        .session(bob)
        .ls("/")
        .submit()?
        .entries()?
        .into_iter()
        .map(|m| m.path)
        .collect();
    println!("bob now sees: {view:?}");
    assert!(view.contains(&"/collab/climate/raw.dat".to_string()));
    assert!(!view.contains(&"/home/alice/notes.txt".to_string()), "Local scope must hide notes");
    match tb.session(bob).read("/home/alice/notes.txt").submit() {
        Err(ScispaceError::NotVisible { .. }) => println!("bob's peek denied: NotVisible (typed)"),
        other => anyhow::bail!("expected NotVisible, got {other:?}"),
    }

    // 5. Bob reads across the WAN through the workspace.
    let data = tb.session(bob).read("/collab/climate/raw.dat").submit()?.data()?;
    assert_eq!(data, b"raw-data");
    println!("bob read raw.dat across sites: {:?}", String::from_utf8_lossy(&data));
    println!("virtual time elapsed: alice={:.6}s bob={:.6}s", tb.now(alice), tb.now(bob));
    println!("quickstart OK");
    Ok(())
}
