//! Concurrent batch execution: lowering `(collaborator, Op)` pairs onto
//! the discrete-event engine so different collaborators genuinely
//! overlap.
//!
//! ## Semantics
//!
//! A batch preserves each collaborator's *program order* — their own
//! ops run serially, in submission order — while ops from different
//! collaborators overlap. Execution proceeds in **waves**: each wave
//! takes the next pending op of every collaborator, and within a wave
//!
//! 1. every op's *front end* (FUSE calls, metadata consults, PFS/NFS
//!    staging) is charged in ascending collaborator-clock order — these
//!    land on FIFO servers, whose completion arithmetic is
//!    admission-order exact;
//! 2. every bulk op's *payload* is then started on the shared links as
//!    weighted engine flows — all of them **before** the event queue is
//!    drained, which is exactly what processor sharing requires (the
//!    engine's per-link causality clamp serializes flows submitted
//!    one-at-a-time); one drain completes the whole wave;
//! 3. each bulk op's *back end* (NFS ingest + flush, destination PFS
//!    write, FUSE copy-out) is charged from its flows' finish time and
//!    the collaborator clocks advance.
//!
//! ## Fidelity trade
//!
//! Bulk payloads here ride priority-weighted flows (the same lowering
//! as [`crate::xfer::run_flows`]) instead of the chunked stop-and-wait
//! stream engine: per-chunk acks and digest offload are not modelled in
//! a batch, in exchange for true link sharing. Single-op [`Session`]
//! calls keep the chunk-exact legacy path bit for bit. Small and
//! local ops execute through the same sequential lowering as single-op
//! calls; their (microsecond-scale) RPCs meet on FIFO metadata servers,
//! where contention is already admission-order exact.
//!
//! Waves are *synchronized rounds*: the engine never rewinds a link, so
//! an op in wave k+1 joins shared links no earlier than wave k's
//! horizon on them. A collaborator's later ops can therefore wait on an
//! unrelated slow op from the previous round (they overlap *within* a
//! round, not across rounds). Workloads mixing very asymmetric op sizes
//! should submit them in separate batches — or extend this executor to
//! event-driven per-collaborator admission (see the ROADMAP "batch
//! lowering fidelity" item).
//!
//! Namespace/payload *state* changes apply at stage time (front end),
//! not at virtual completion — a concurrent read in the same wave can
//! observe a write staged before it even though their completion times
//! overlap. This mirrors the legacy sequential semantics (execution
//! order decides visibility, virtual clocks decide cost), with wave
//! order standing in for execution order.
//!
//! [`Session`]: crate::api::Session

use std::collections::VecDeque;

use crate::api::{exec_op, Op, OpResult, ScispaceError};
use crate::engine::FlowId;
use crate::sds::Sds;
use crate::vfs::ObjectId;
use crate::workspace::{AccessMode, Testbed};
use crate::xfer::{path_loss_baseline, path_loss_delta, Priority, TransferReport};

/// Run a batch with a discovery service attached, so [`Op::Query`] and
/// [`Op::Tag`] are executable alongside workspace ops. Same semantics
/// as [`Testbed::run_batch`].
pub fn run_batch_with_sds(tb: &mut Testbed, sds: &mut Sds, ops: Vec<(usize, Op)>) -> Vec<OpResult> {
    run_batch(tb, Some(sds), ops)
}

/// What a staged bulk op still owes after its front end was charged.
enum PlanKind {
    Read { obj: ObjectId, offset: u64, len: u64 },
    Write { path: String, obj: ObjectId, dtn: usize, data_dc: usize, offset: u64, len: u64 },
    Replicate { path: String, src_obj: ObjectId, size: u64, driver: String },
}

/// One bulk op lowered onto the engine: front end charged, payload
/// flows pending.
struct BulkPlan {
    idx: usize,
    c: usize,
    kind: PlanKind,
    src_dc: usize,
    dst_dc: usize,
    bytes: u64,
    weight: f64,
    ready: f64,
    /// Started flows with the byte count each one carries.
    flows: Vec<(FlowId, u64)>,
    /// Per-hop congestion baseline captured at launch (for the
    /// [`crate::xfer::PathLoss`] deltas in the replicate report).
    loss_base: Vec<(u64, u64)>,
}

enum Staged {
    Plan(Box<BulkPlan>),
    Sequential(Op),
}

pub(crate) fn run_batch(
    tb: &mut Testbed,
    mut sds: Option<&mut Sds>,
    ops: Vec<(usize, Op)>,
) -> Vec<OpResult> {
    let n = ops.len();
    let mut results: Vec<Option<OpResult>> = (0..n).map(|_| None).collect();
    let n_collabs = tb.collabs.len();
    let mut queues: Vec<VecDeque<(usize, Op)>> = vec![VecDeque::new(); n_collabs];
    for (idx, (c, op)) in ops.into_iter().enumerate() {
        if c >= n_collabs {
            results[idx] = Some(OpResult::Failed(ScispaceError::Unsupported {
                msg: format!("collaborator {c} not registered"),
            }));
        } else {
            queues[c].push_back((idx, op));
        }
    }

    loop {
        let mut wave: Vec<(usize, usize, Op)> = Vec::new();
        for (c, q) in queues.iter_mut().enumerate() {
            if let Some((idx, op)) = q.pop_front() {
                wave.push((idx, c, op));
            }
        }
        if wave.is_empty() {
            break;
        }
        // deterministic admission order: earliest collaborator clock
        // first, collaborator index as the tie-break
        wave.sort_by(|a, b| {
            tb.collabs[a.1].now.total_cmp(&tb.collabs[b.1].now).then(a.1.cmp(&b.1))
        });

        // 1. front ends (and whole small/local ops) run sequentially
        let mut plans: Vec<Box<BulkPlan>> = Vec::new();
        for (idx, c, op) in wave {
            match try_stage(tb, c, idx, op) {
                Ok(Staged::Plan(p)) => plans.push(p),
                Ok(Staged::Sequential(op)) => {
                    let r = match exec_op(tb, c, sds.as_deref_mut(), op) {
                        Ok(r) => r,
                        Err(e) => OpResult::Failed(e),
                    };
                    results[idx] = Some(r);
                }
                Err(e) => results[idx] = Some(OpResult::Failed(e)),
            }
        }

        // 2. every plan's flows start before the single drain — this is
        // the step that turns serialize-behind-the-horizon into
        // processor sharing
        for plan in &mut plans {
            launch(tb, plan);
        }
        tb.env.run_until_idle();

        // 3. back ends and results
        for plan in plans {
            let (idx, r) = finish(tb, *plan);
            results[idx] = Some(r);
        }
    }

    results.into_iter().map(|r| r.expect("every op resolved")).collect()
}

/// Charge an op's front end and produce its flow plan — or hand it back
/// for sequential execution when it has no shareable bulk payload.
fn try_stage(tb: &mut Testbed, c: usize, idx: usize, op: Op) -> Result<Staged, ScispaceError> {
    match op {
        Op::Read { ref path, offset, len, mode } if mode != AccessMode::ScispaceLw => {
            // uncharged peek for classification; the charged lookup
            // happens in whichever lowering actually runs
            let Some((data_dc, obj)) = tb.locate(path) else {
                return Ok(Staged::Sequential(op));
            };
            let len = match len {
                Some(l) => l,
                None => tb.dcs[data_dc].store.len(obj).unwrap_or(0).saturating_sub(offset),
            };
            let home_dc = tb.collabs[c].dc;
            if data_dc == home_dc || len < tb.cfg.xfer_threshold {
                return Ok(Staged::Sequential(op));
            }
            let path = path.clone();
            let (data_dc, obj) = tb
                .locate_for(c, &path)
                .ok_or_else(|| ScispaceError::NoSuchFile { path: path.clone() })?;
            let viewer = tb.collabs[c].id.clone();
            if !tb.ns.visible_to(&path, &viewer) {
                return Err(ScispaceError::NotVisible { path, viewer });
            }
            let (ready, _dtn) =
                tb.read_stage_frontend(c, &path, obj, data_dc, offset, len, mode);
            Ok(Staged::Plan(Box::new(BulkPlan {
                idx,
                c,
                kind: PlanKind::Read { obj, offset, len },
                src_dc: data_dc,
                dst_dc: home_dc,
                bytes: len,
                weight: Priority::Interactive.weight(),
                ready,
                flows: Vec::new(),
                loss_base: Vec::new(),
            })))
        }
        Op::Write { ref path, offset, len, ref data, mode }
            if mode != AccessMode::ScispaceLw && len >= tb.cfg.xfer_threshold =>
        {
            let path = path.clone();
            let home_dc = tb.collabs[c].dc;
            let dtn = tb.collabs[c].dtn;
            let (ready, obj, data_dc) =
                tb.write_frontend(c, &path, offset, len, data.as_deref(), mode)?;
            Ok(Staged::Plan(Box::new(BulkPlan {
                idx,
                c,
                kind: PlanKind::Write { path, obj, dtn, data_dc, offset, len },
                src_dc: home_dc,
                dst_dc: data_dc,
                bytes: len,
                weight: Priority::Interactive.weight(),
                ready,
                flows: Vec::new(),
                loss_base: Vec::new(),
            })))
        }
        Op::Replicate { ref path, dst_dc } => {
            let path = path.clone();
            let (ready, src_dc, obj, size, driver) = tb.replicate_frontend(c, &path, dst_dc)?;
            Ok(Staged::Plan(Box::new(BulkPlan {
                idx,
                c,
                kind: PlanKind::Replicate { path, src_obj: obj, size, driver },
                src_dc,
                dst_dc,
                bytes: size,
                weight: Priority::Bulk.weight(),
                ready,
                flows: Vec::new(),
                loss_base: Vec::new(),
            })))
        }
        other => Ok(Staged::Sequential(other)),
    }
}

/// Split a plan's payload into `n_streams` weighted flows and start
/// them (not drained here — the caller drains once per wave).
fn launch(tb: &mut Testbed, plan: &mut BulkPlan) {
    // counters only move while the queue drains, so a baseline taken at
    // any launch in the wave sees the same pre-drain state
    plan.loss_base = path_loss_baseline(&tb.env, &tb.net, plan.src_dc, plan.dst_dc);
    tb.net.begin_transfer(plan.src_dc, plan.dst_dc);
    if plan.bytes == 0 {
        return;
    }
    let path = tb.net.flow_path(plan.src_dc, plan.dst_dc);
    let cfg = &tb.cfg.xfer;
    let n = (cfg.n_streams.max(1) as u64).min(plan.bytes);
    let per = plan.bytes / n;
    let extra = plan.bytes % n;
    let t0 = plan.ready + cfg.stream_setup_s;
    for k in 0..n {
        let b = per + u64::from(k < extra);
        let f = if cfg.cc.enabled {
            let window = cfg.cc.window;
            tb.env.start_windowed_flow(&path, b, t0, plan.weight, &window)
        } else {
            tb.env.start_flow(&path, b, t0, plan.weight)
        };
        plan.flows.push((f, b));
    }
}

/// Charge a plan's back end from its flows' finish time, advance the
/// collaborator clock, and materialize the result.
fn finish(tb: &mut Testbed, plan: BulkPlan) -> (usize, OpResult) {
    let BulkPlan { idx, c, kind, src_dc, dst_dc, bytes: _, weight: _, ready, flows, loss_base } =
        plan;
    tb.net.end_transfer(src_dc, dst_dc);
    let setup = tb.cfg.xfer.stream_setup_s;
    let tf = flows
        .iter()
        .filter_map(|&(f, _)| tb.env.flow_finish(f))
        .fold(ready + if flows.is_empty() { 0.0 } else { setup }, f64::max);
    let r = match kind {
        PlanKind::Read { obj, offset, len } => {
            let fi = tb.collabs[c].fuse;
            let copy = tb.fuse_mounts[fi].copy;
            let t_end = tb.env.serve(copy, tf, len);
            tb.collabs[c].now = t_end;
            match tb.dcs[src_dc].store.read_at(obj, offset, len as usize) {
                Ok(bytes) => OpResult::Data { bytes, finished_at: t_end },
                Err(e) => OpResult::Failed(e.into()),
            }
        }
        PlanKind::Write { path, obj, dtn, data_dc, offset, len } => {
            let (tn, flush) = tb.dtns[dtn].nfs.write(&mut tb.env, tf, obj.0, offset, len);
            let mut t2 = tn;
            if let Some(fb) = flush {
                t2 = t2.max(tb.dtns[dtn].nfs.pending_flush);
                let end = tb.dcs[data_dc].lustre.write(&mut tb.env, t2, obj.0, offset, fb);
                tb.dtns[dtn].nfs.pending_flush = end;
            }
            tb.collabs[c].now = t2;
            OpResult::Written { path, bytes: len, finished_at: t2 }
        }
        PlanKind::Replicate { path, src_obj, size, driver } => {
            let ctx =
                ReplicaCtx { c, src_dc, dst_dc, ready, tf, flows: &flows, loss_base: &loss_base };
            match materialize_replica(tb, &ctx, &path, src_obj, size, driver) {
                Ok(rep) => OpResult::Replicated(rep),
                Err(e) => OpResult::Failed(e),
            }
        }
    };
    (idx, r)
}

/// The plan context a replicate back end needs (split from [`BulkPlan`]
/// so the plan's `kind` can be consumed independently).
struct ReplicaCtx<'a> {
    c: usize,
    src_dc: usize,
    dst_dc: usize,
    ready: f64,
    tf: f64,
    flows: &'a [(FlowId, u64)],
    loss_base: &'a [(u64, u64)],
}

fn materialize_replica(
    tb: &mut Testbed,
    ctx: &ReplicaCtx<'_>,
    path: &str,
    src_obj: ObjectId,
    size: u64,
    driver: String,
) -> Result<TransferReport, ScispaceError> {
    let (src_dc, dst_dc, tf) = (ctx.src_dc, ctx.dst_dc, ctx.tf);
    let replica = tb.clone_replica(path, src_dc, dst_dc, src_obj, size)?;
    let t_done = tb.dcs[dst_dc].lustre.write(&mut tb.env, tf, replica.0, 0, size);
    tb.collabs[ctx.c].now = tb.collabs[ctx.c].now.max(t_done);

    // adaptive-tuning signals: per-flow goodput + this wave's per-link
    // loss deltas along the path (shared-wave attribution)
    let setup = tb.cfg.xfer.stream_setup_s;
    let stream_goodput: Vec<f64> = ctx
        .flows
        .iter()
        .map(|&(f, b)| match tb.env.flow_finish(f) {
            Some(end) if end > ctx.ready + setup => b as f64 / (end - ctx.ready - setup),
            _ => 0.0,
        })
        .collect();
    let path_losses = path_loss_delta(&tb.env, &tb.net, src_dc, dst_dc, ctx.loss_base);
    Ok(TransferReport {
        id: tb.next_xfer_id(),
        owner: driver,
        priority: Priority::Bulk,
        bytes: size,
        chunks: 0, // flow-level lowering: no chunk accounting in batches
        streams: ctx.flows.len(),
        retried_chunks: 0,
        retried_bytes: 0,
        stream_drops: 0,
        cc_losses: ctx.flows.iter().map(|&(f, _)| tb.env.flow_losses(f)).sum(),
        cc_retransmit_bytes: ctx
            .flows
            .iter()
            .map(|&(f, _)| tb.env.flow_retransmitted_bytes(f))
            .sum(),
        started_at: ctx.ready,
        finished_at: tf,
        stream_goodput,
        path_losses,
    })
}
