//! Transfer scheduling: a priority + per-collaboration fair-share queue
//! and a chunk-interleaved dispatcher for concurrent transfers.
//!
//! Admission (which pending transfer starts next) is strict-priority,
//! tie-broken by the collaboration that has consumed the least weighted
//! service, then FIFO. Once admitted, concurrent flights share the
//! links chunk-by-chunk: each dispatch goes to the active flight with
//! the least `delivered_bytes / weight`, which converges to weighted
//! fair sharing of the bottleneck link — the contention behaviour
//! concurrent collaborations actually see on a DTN's WAN uplink.

use std::collections::HashMap;

use anyhow::Result;

use crate::simclock::SimEnv;
use crate::simnet::Network;

use super::{FaultInjector, Flight, TransferReport, TransferRequest, XferEngine};

/// Pending transfers with priority + fair-share admission.
#[derive(Debug, Default)]
pub struct TransferQueue {
    pending: Vec<TransferRequest>,
    /// Weighted bytes served so far, per collaboration.
    served: HashMap<String, f64>,
}

impl TransferQueue {
    /// Empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue a transfer request.
    pub fn submit(&mut self, req: TransferRequest) {
        self.pending.push(req);
    }

    /// Pending transfers.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// True when nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Record weighted service for `owner` (called by the dispatcher as
    /// transfers complete so later admissions stay fair).
    pub fn note_served(&mut self, owner: &str, weighted_bytes: f64) {
        *self.served.entry(owner.to_string()).or_insert(0.0) += weighted_bytes;
    }

    /// Weighted service consumed by `owner` so far.
    pub fn served(&self, owner: &str) -> f64 {
        self.served.get(owner).copied().unwrap_or(0.0)
    }

    /// Admit the next transfer: highest priority class first; within a
    /// class the collaboration with the least weighted service; FIFO as
    /// the final tie-break (stable: earliest submission wins).
    pub fn pop_next(&mut self) -> Option<TransferRequest> {
        let mut best: Option<usize> = None;
        for i in 0..self.pending.len() {
            let better = match best {
                None => true,
                Some(b) => {
                    let (pb, pi) = (&self.pending[b], &self.pending[i]);
                    match pi.priority.cmp(&pb.priority) {
                        std::cmp::Ordering::Greater => true,
                        std::cmp::Ordering::Less => false,
                        std::cmp::Ordering::Equal => {
                            self.served(&pi.owner) < self.served(&pb.owner)
                        }
                    }
                }
            };
            if better {
                best = Some(i);
            }
        }
        best.map(|i| self.pending.remove(i))
    }
}

/// Drain `queue` through `engine`, running up to `max_concurrent`
/// transfers at once. Active flights interleave chunk dispatches by
/// least weighted service, so concurrent collaborations split the
/// bottleneck links by priority weight. Returns reports in completion
/// order.
pub fn run_queue(
    engine: &XferEngine,
    env: &mut SimEnv,
    net: &mut Network,
    queue: &mut TransferQueue,
    faults: &mut FaultInjector,
    now: f64,
    max_concurrent: usize,
) -> Result<Vec<TransferReport>> {
    let max_concurrent = max_concurrent.max(1);
    let mut flights: Vec<Flight> = Vec::new();
    let mut out = Vec::new();
    let mut admit_at = now;

    let admit = |flights: &mut Vec<Flight>,
                 queue: &mut TransferQueue,
                 net: &mut Network,
                 at: f64| {
        while flights.len() < max_concurrent {
            let Some(req) = queue.pop_next() else { break };
            net.begin_transfer(req.src_dc, req.dst_dc);
            let start = at.max(req.submitted_at);
            flights.push(Flight::new(&engine.cfg, net, &req, start));
        }
    };
    admit(&mut flights, queue, net, admit_at);

    while !flights.is_empty() {
        // fair-share dispatch: least weighted service goes next
        let mut pick = 0;
        for i in 1..flights.len() {
            if flights[i].weighted_service() < flights[pick].weighted_service() {
                pick = i;
            }
        }
        let step = flights[pick].step(&engine.cfg, env, faults);
        if step.is_err() || flights[pick].is_done() {
            let flight = flights.swap_remove(pick);
            net.end_transfer(flight.req.src_dc, flight.req.dst_dc);
            if let Err(e) = step {
                // release the contention registrations of every other
                // in-flight transfer before propagating
                for f in &flights {
                    net.end_transfer(f.req.src_dc, f.req.dst_dc);
                }
                return Err(e);
            }
            let report = flight.into_report();
            queue.note_served(
                &report.owner,
                report.bytes as f64 / report.priority.weight(),
            );
            admit_at = admit_at.max(report.finished_at);
            out.push(report);
            admit(&mut flights, queue, net, admit_at);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::{NetConfig, Network};
    use crate::xfer::{Priority, XferConfig};

    fn setup() -> (SimEnv, Network, XferEngine) {
        let mut env = SimEnv::new();
        let net = Network::build(&mut env, &NetConfig::paper_default(), 2);
        (env, net, XferEngine::new(XferConfig::default()))
    }

    fn req(id: u64, owner: &str, bytes: u64, priority: Priority) -> TransferRequest {
        TransferRequest {
            id,
            owner: owner.to_string(),
            src_dc: 0,
            dst_dc: 1,
            bytes,
            priority,
            submitted_at: 0.0,
        }
    }

    #[test]
    fn pop_respects_priority_then_fairness() {
        let mut q = TransferQueue::new();
        q.submit(req(1, "a", 1 << 20, Priority::Scavenger));
        q.submit(req(2, "b", 1 << 20, Priority::Interactive));
        q.submit(req(3, "c", 1 << 20, Priority::Bulk));
        assert_eq!(q.pop_next().unwrap().id, 2, "interactive first");
        assert_eq!(q.pop_next().unwrap().id, 3, "bulk second");
        assert_eq!(q.pop_next().unwrap().id, 1);
        assert!(q.pop_next().is_none());
    }

    #[test]
    fn fairness_prefers_underserved_collaboration() {
        let mut q = TransferQueue::new();
        q.note_served("greedy", 1e9);
        q.submit(req(1, "greedy", 1 << 20, Priority::Bulk));
        q.submit(req(2, "modest", 1 << 20, Priority::Bulk));
        assert_eq!(q.pop_next().unwrap().id, 2, "underserved owner first");
    }

    #[test]
    fn concurrent_equal_transfers_finish_together() {
        let (mut env, mut net, engine) = setup();
        let mut q = TransferQueue::new();
        q.submit(req(1, "a", 64 << 20, Priority::Bulk));
        q.submit(req(2, "b", 64 << 20, Priority::Bulk));
        let reps = run_queue(
            &engine, &mut env, &mut net, &mut q, &mut FaultInjector::none(), 0.0, 2,
        )
        .unwrap();
        assert_eq!(reps.len(), 2);
        let (f1, f2) = (reps[0].finished_at, reps[1].finished_at);
        let skew = (f1 - f2).abs() / f1.max(f2);
        assert!(skew < 0.15, "equal-weight transfers should finish together: {f1} vs {f2}");
        // both shared the WAN: total bytes conserved
        assert_eq!(env.resource(net.wan.res).total_bytes, 128 << 20);
    }

    #[test]
    fn interactive_beats_bulk_under_contention() {
        let (mut env, mut net, engine) = setup();
        let mut q = TransferQueue::new();
        q.submit(req(1, "bulk-a", 64 << 20, Priority::Bulk));
        q.submit(req(2, "urgent", 64 << 20, Priority::Interactive));
        let reps = run_queue(
            &engine, &mut env, &mut net, &mut q, &mut FaultInjector::none(), 0.0, 2,
        )
        .unwrap();
        let urgent = reps.iter().find(|r| r.owner == "urgent").unwrap();
        let bulk = reps.iter().find(|r| r.owner == "bulk-a").unwrap();
        assert!(
            urgent.finished_at < bulk.finished_at,
            "interactive {} must finish before bulk {}",
            urgent.finished_at,
            bulk.finished_at
        );
    }

    #[test]
    fn concurrency_limit_serializes_excess() {
        let (mut env, mut net, engine) = setup();
        let mut q = TransferQueue::new();
        for i in 0..3 {
            q.submit(req(i, &format!("o{i}"), 16 << 20, Priority::Bulk));
        }
        let reps = run_queue(
            &engine, &mut env, &mut net, &mut q, &mut FaultInjector::none(), 0.0, 1,
        )
        .unwrap();
        assert_eq!(reps.len(), 3);
        // with max_concurrent=1 each next transfer starts after the prior
        for w in reps.windows(2) {
            assert!(w[1].started_at >= w[0].finished_at - 1e-9);
        }
        // contention accounting saw one transfer at a time
        assert_eq!(net.wan_peak(), 1);
    }

    #[test]
    fn failed_transfer_releases_all_contention() {
        let (mut env, mut net, _) = setup();
        let engine = XferEngine::new(XferConfig { max_retries: 1, ..XferConfig::default() });
        let mut q = TransferQueue::new();
        q.submit(req(1, "a", 16 << 20, Priority::Bulk));
        q.submit(req(2, "b", 16 << 20, Priority::Bulk));
        let mut faults = FaultInjector::with_seed(3);
        faults.corrupt_rate = 1.0; // every delivery corrupt -> budget blown
        let res = run_queue(&engine, &mut env, &mut net, &mut q, &mut faults, 0.0, 2);
        assert!(res.is_err());
        assert_eq!(net.wan_active(), 0, "error path must release every registration");
        assert_eq!(net.lan_active(0), 0);
        assert_eq!(net.lan_active(1), 0);
    }

    #[test]
    fn concurrent_transfers_raise_peak_contention() {
        let (mut env, mut net, engine) = setup();
        let mut q = TransferQueue::new();
        for i in 0..3 {
            q.submit(req(i, &format!("o{i}"), 16 << 20, Priority::Bulk));
        }
        run_queue(&engine, &mut env, &mut net, &mut q, &mut FaultInjector::none(), 0.0, 3)
            .unwrap();
        assert_eq!(net.wan_peak(), 3);
        assert_eq!(net.wan_active(), 0, "all transfers ended");
    }
}
