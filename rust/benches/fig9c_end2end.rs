//! Fig. 9c: end-to-end scientific collaboration with H5Diff — baseline
//! (filename search + migrate datasets to the local DC + run) vs
//! SCISPACE (attribute query + run in place).
//!
//! Paper shape: SCISPACE's end-to-end time is lower for every file
//! count, and the gap widens with files (baseline search + migration
//! grow; query time is ~constant). Uses the PJRT diff kernel when
//! `artifacts/` is built. Run: `cargo bench --bench fig9c_end2end`.

use scispace::bench::{fig9c, print_end2end};
use scispace::runtime;

fn main() {
    let svc = runtime::find_artifacts().and_then(|d| runtime::ComputeService::spawn(&d).ok());
    let rows = match &svc {
        Some(s) => {
            println!("(diff compute: PJRT kernel)");
            let h = s.handle();
            let mut f = move |a: &[f32], b: &[f32], tol: f32| {
                let r = h.diff(a, b, tol).expect("pjrt diff");
                (r.n_diff, r.max_abs, r.sum_sq)
            };
            fig9c(&[8, 16, 32, 64], Some(&mut f))
        }
        None => {
            println!("(diff compute: CPU fallback — run `make artifacts`)");
            fig9c(&[8, 16, 32, 64], None)
        }
    };
    print_end2end(&rows);
}
