//! The public Session API: typed operations, per-collaborator handles,
//! and concurrent batch submission over the discrete-event engine.
//!
//! This module is the user-facing surface of the workspace. Three layers:
//!
//! * [`Session`] — a per-collaborator handle created with
//!   [`Testbed::session`] (or [`Session::new`]). Every collaborator
//!   operation — `read`, `write`, `ls`, `locate`, `replicate`, `query`,
//!   `tag`, `write_indexed` — is a builder-style typed call:
//!
//!   ```ignore
//!   let mut sess = tb.session(alice);
//!   sess.write("/collab/a.dat").data(b"payload").submit()?;
//!   let bytes = sess.read("/collab/a.dat").len(7).submit()?.data()?;
//!   ```
//!
//! * [`Op`] / [`OpResult`] — the unified request/response model the
//!   builders lower onto, covering workspace, SDS and metadata
//!   operations, with one typed [`ScispaceError`] (`NotVisible`,
//!   `NotLocal`, `NoSuchFile`, ...) replacing ad-hoc string errors.
//!   Builders also convert into bare [`Op`]s ([`WriteBuilder::into_op`]
//!   etc.) for batch composition.
//!
//! * [`Testbed::run_batch`] — lowers a whole batch of `(collaborator,
//!   Op)` pairs onto the event engine so operations from *different*
//!   collaborators genuinely overlap: each collaborator is admitted
//!   independently by engine control events, and bulk payloads run the
//!   same chunked stop-and-wait transfer machinery as single-op calls
//!   (chunks from concurrent transfers share FUSE mounts, metadata
//!   shards and WAN links under processor sharing; a batch of one is
//!   bit-identical to the single-op call — see [`batch`] for the exact
//!   lowering and the admission-time visibility rule).
//!
//! The legacy positional-argument methods on [`Testbed`]
//! (`tb.write(c, path, ...)`) remain as thin `pub(crate)` internals;
//! single-op Session calls produce bit-identical completion times to
//! them (pinned by the equivalence tests below).

pub mod batch;
mod error;

pub use batch::{BatchOutcome, TimedOp};
pub use error::ScispaceError;

use crate::db::Value;
use crate::metadata::FileMeta;
use crate::sds::{ExtractionMode, Query, Sds, StatsFn};
use crate::shdf::ShdfFile;
use crate::workspace::{AccessMode, Testbed};
use crate::xfer::{FaultInjector, TransferReport};

/// One typed collaborator operation (the request half of the model).
///
/// `Op`s are built directly or via the [`Session`] builders
/// (`sess.write(p).len(n).into_op()`), and executed by
/// [`Session`] submit calls or [`Testbed::run_batch`].
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// POSIX-like write (create-if-missing).
    Write {
        /// Workspace path.
        path: String,
        /// Byte offset.
        offset: u64,
        /// Payload length (derived from `data` when present).
        len: u64,
        /// Real bytes to store; `None` simulates a synthetic payload.
        data: Option<Vec<u8>>,
        /// Access path through the stack.
        mode: AccessMode,
    },
    /// POSIX-like read.
    Read {
        /// Workspace path.
        path: String,
        /// Byte offset.
        offset: u64,
        /// Bytes to read; `None` = the rest of the file.
        len: Option<u64>,
        /// Access path through the stack.
        mode: AccessMode,
    },
    /// Workspace listing (metadata fan-out + visibility filter).
    Ls {
        /// Path prefix to list.
        prefix: String,
    },
    /// Resolve where a path's payload lives.
    Locate {
        /// Workspace path.
        path: String,
    },
    /// Replicate a payload into another data center through the bulk
    /// transfer engine.
    Replicate {
        /// Workspace path.
        path: String,
        /// Destination data center.
        dst_dc: usize,
    },
    /// Attribute query against the discovery shards.
    Query {
        /// Parsed query predicate.
        query: Query,
    },
    /// Collaborator-defined tagging of an indexed file.
    Tag {
        /// Workspace path.
        path: String,
        /// Attribute name.
        attr: String,
        /// Attribute value.
        value: Value,
    },
}

impl Op {
    /// Short kind label (`write`, `read`, ...) — the flight recorder
    /// names an op's span `op:<kind>`.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Op::Write { .. } => "write",
            Op::Read { .. } => "read",
            Op::Ls { .. } => "ls",
            Op::Locate { .. } => "locate",
            Op::Replicate { .. } => "replicate",
            Op::Query { .. } => "query",
            Op::Tag { .. } => "tag",
        }
    }
}

/// The response half of the typed model: one variant per [`Op`] kind,
/// plus [`OpResult::Failed`] so a batch can report per-op errors
/// without aborting.
#[derive(Debug, Clone)]
pub enum OpResult {
    /// A write completed.
    Written {
        /// Path written.
        path: String,
        /// Bytes written.
        bytes: u64,
        /// Collaborator-visible completion time.
        finished_at: f64,
        /// The striped ingest transfer's report — the same adaptive-
        /// tuning signal set ([`TransferReport::stream_goodput`],
        /// [`TransferReport::path_losses`], [`TransferReport::tune`])
        /// the replicate path carries. `None` when the payload rode the
        /// plain route (below the bulk threshold or native-mode).
        transfer: Option<Box<TransferReport>>,
    },
    /// A read completed.
    Data {
        /// The payload (zeros for synthetic objects).
        bytes: Vec<u8>,
        /// Collaborator-visible completion time.
        finished_at: f64,
        /// The striped WAN transfer's report (see
        /// [`OpResult::Written`]); `None` for local or sub-threshold
        /// reads, which never stripe.
        transfer: Option<Box<TransferReport>>,
    },
    /// A listing completed.
    Listing {
        /// Visible entries under the prefix.
        entries: Vec<FileMeta>,
        /// Collaborator-visible completion time.
        finished_at: f64,
    },
    /// A locate completed.
    Located {
        /// Data center holding the payload.
        dc: usize,
        /// Payload size, bytes.
        size: u64,
        /// Collaborator-visible completion time.
        finished_at: f64,
    },
    /// A replication completed. The report carries the adaptive-tuning
    /// signal set: per-stream goodput ([`TransferReport::stream_goodput`])
    /// and per-path loss deltas ([`TransferReport::path_losses`]).
    Replicated(TransferReport),
    /// A query completed.
    Hits {
        /// Matching file paths (sorted, deduplicated).
        files: Vec<String>,
        /// Query latency, virtual seconds.
        latency_s: f64,
        /// Collaborator-visible completion time.
        finished_at: f64,
    },
    /// A tag was applied.
    Tagged {
        /// Collaborator-visible completion time.
        finished_at: f64,
    },
    /// The operation failed (typed).
    Failed(ScispaceError),
}

impl OpResult {
    /// Completion time of a successful op (`NAN` for [`OpResult::Failed`]).
    pub fn finished_at(&self) -> f64 {
        match self {
            OpResult::Written { finished_at, .. }
            | OpResult::Data { finished_at, .. }
            | OpResult::Listing { finished_at, .. }
            | OpResult::Located { finished_at, .. }
            | OpResult::Hits { finished_at, .. }
            | OpResult::Tagged { finished_at } => *finished_at,
            OpResult::Replicated(rep) => rep.finished_at,
            OpResult::Failed(_) => f64::NAN,
        }
    }

    /// True unless this is [`OpResult::Failed`].
    pub fn is_ok(&self) -> bool {
        !matches!(self, OpResult::Failed(_))
    }

    /// The typed error, when failed.
    pub fn err(&self) -> Option<&ScispaceError> {
        match self {
            OpResult::Failed(e) => Some(e),
            _ => None,
        }
    }

    fn unexpected(self, wanted: &str) -> ScispaceError {
        match self {
            OpResult::Failed(e) => e,
            other => ScispaceError::Internal {
                msg: format!("expected {wanted}, got {other:?}"),
            },
        }
    }

    /// Unwrap a read result into its payload.
    pub fn data(self) -> Result<Vec<u8>, ScispaceError> {
        match self {
            OpResult::Data { bytes, .. } => Ok(bytes),
            other => Err(other.unexpected("Data")),
        }
    }

    /// Unwrap a listing result into its entries.
    pub fn entries(self) -> Result<Vec<FileMeta>, ScispaceError> {
        match self {
            OpResult::Listing { entries, .. } => Ok(entries),
            other => Err(other.unexpected("Listing")),
        }
    }

    /// Unwrap a locate result into `(dc, size)`.
    pub fn located(self) -> Result<(usize, u64), ScispaceError> {
        match self {
            OpResult::Located { dc, size, .. } => Ok((dc, size)),
            other => Err(other.unexpected("Located")),
        }
    }

    /// Unwrap a replication result into its transfer report.
    pub fn replicated(self) -> Result<TransferReport, ScispaceError> {
        match self {
            OpResult::Replicated(rep) => Ok(rep),
            other => Err(other.unexpected("Replicated")),
        }
    }

    /// Unwrap a query result into its matching files.
    pub fn files(self) -> Result<Vec<String>, ScispaceError> {
        match self {
            OpResult::Hits { files, .. } => Ok(files),
            other => Err(other.unexpected("Hits")),
        }
    }
}

/// A per-collaborator handle over the testbed: the entry point for every
/// typed operation. Short-lived and cheap — create one per scope (it
/// exclusively borrows the testbed).
pub struct Session<'t> {
    tb: &'t mut Testbed,
    c: usize,
}

impl Testbed {
    /// Open a [`Session`] for a registered collaborator.
    pub fn session(&mut self, c: usize) -> Session<'_> {
        assert!(c < self.collabs.len(), "collaborator {c} not registered");
        Session { tb: self, c }
    }

    /// Execute a batch of typed operations, overlapping operations from
    /// different collaborators on the shared engine (each collaborator's
    /// own ops stay serial, in submission order). Results are returned
    /// in submission order; failures are reported per-op as
    /// [`OpResult::Failed`] without aborting the batch.
    ///
    /// SDS operations ([`Op::Query`], [`Op::Tag`]) need a discovery
    /// service — use [`batch::run_batch_with_sds`] for mixed batches.
    pub fn run_batch(&mut self, ops: Vec<(usize, Op)>) -> Vec<OpResult> {
        batch::run_batch(self, None, ops)
    }

    /// Execute a batch in **open-loop** mode: each [`TimedOp`] carries a
    /// scheduled virtual arrival time and is pushed into the bed at that
    /// time regardless of in-flight work, so the arrival process — not
    /// the system's service speed — sets the offered load. Per-op
    /// outcomes report queueing delay (arrival → admission) separately
    /// from service latency; see [`batch`]'s "Open-loop admission".
    ///
    /// Results are returned in submission order. SDS operations need a
    /// discovery service — use [`batch::run_batch_open_with_sds`].
    pub fn run_batch_open(&mut self, ops: Vec<TimedOp>) -> Vec<BatchOutcome> {
        batch::run_batch_open(self, None, ops)
    }
}

impl<'t> Session<'t> {
    /// Open a session for collaborator `c` (equivalent to
    /// [`Testbed::session`]).
    pub fn new(tb: &'t mut Testbed, c: usize) -> Self {
        assert!(c < tb.collabs.len(), "collaborator {c} not registered");
        Session { tb, c }
    }

    /// The collaborator this session acts as.
    pub fn collab(&self) -> usize {
        self.c
    }

    /// The collaborator's current virtual time.
    pub fn now(&self) -> f64 {
        self.tb.now(self.c)
    }

    /// Advance the collaborator's clock by `seconds` of client-side work
    /// the testbed does not model (e.g. local analysis compute).
    pub fn advance(&mut self, seconds: f64) {
        self.tb.collabs[self.c].now += seconds;
    }

    /// Build a write (defaults: offset 0, length 0, synthetic payload,
    /// [`AccessMode::Scispace`]).
    pub fn write(&mut self, path: &str) -> WriteBuilder<'_, 't> {
        WriteBuilder {
            sess: self,
            path: path.to_string(),
            offset: 0,
            len: None,
            data: None,
            mode: AccessMode::Scispace,
        }
    }

    /// Build a read (defaults: offset 0, whole file,
    /// [`AccessMode::Scispace`]).
    pub fn read(&mut self, path: &str) -> ReadBuilder<'_, 't> {
        ReadBuilder {
            sess: self,
            path: path.to_string(),
            offset: 0,
            len: None,
            mode: AccessMode::Scispace,
        }
    }

    /// Build a workspace listing under `prefix`.
    pub fn ls(&mut self, prefix: &str) -> LsBuilder<'_, 't> {
        LsBuilder { sess: self, prefix: prefix.to_string() }
    }

    /// Build a locate of `path`.
    pub fn locate(&mut self, path: &str) -> LocateBuilder<'_, 't> {
        LocateBuilder { sess: self, path: path.to_string() }
    }

    /// Build a replication of `path` (destination set with
    /// [`ReplicateBuilder::to`]).
    pub fn replicate(&mut self, path: &str) -> ReplicateBuilder<'_, 't, '_> {
        ReplicateBuilder { sess: self, path: path.to_string(), dst_dc: None, faults: None }
    }

    /// Build an attribute query against the discovery service (text is
    /// parsed at submit; `attr op value` with `=`, `<`, `>`, `like`).
    pub fn query<'s>(&mut self, sds: &'s mut Sds, text: &str) -> QueryBuilder<'_, 't, 's> {
        QueryBuilder { sess: self, sds, text: text.to_string(), parsed: None }
    }

    /// Build a query from an already-parsed predicate.
    pub fn query_parsed<'s>(&mut self, sds: &'s mut Sds, q: Query) -> QueryBuilder<'_, 't, 's> {
        QueryBuilder { sess: self, sds, text: String::new(), parsed: Some(q) }
    }

    /// Build a tag of `path` with `attr = value`.
    pub fn tag<'s>(
        &mut self,
        sds: &'s mut Sds,
        path: &str,
        attr: &str,
        value: Value,
    ) -> TagBuilder<'_, 't, 's> {
        TagBuilder {
            sess: self,
            sds,
            path: path.to_string(),
            attr: attr.to_string(),
            value,
        }
    }

    /// Build an SDS-indexed SHDF write (defaults:
    /// [`ExtractionMode::InlineSync`], no derived stats).
    pub fn write_indexed<'s, 'f>(
        &mut self,
        sds: &'s mut Sds,
        path: &str,
        file: &'f ShdfFile,
    ) -> WriteIndexedBuilder<'_, 't, 's, 'f> {
        WriteIndexedBuilder {
            sess: self,
            sds,
            path: path.to_string(),
            file,
            xmode: ExtractionMode::InlineSync,
        }
    }

    /// Execute one typed [`Op`] (workspace/metadata ops only; SDS ops
    /// need [`Session::submit_with_sds`]).
    pub fn submit(&mut self, op: Op) -> Result<OpResult, ScispaceError> {
        exec_op(self.tb, self.c, None, op)
    }

    /// Execute one typed [`Op`] with a discovery service attached.
    pub fn submit_with_sds(&mut self, sds: &mut Sds, op: Op) -> Result<OpResult, ScispaceError> {
        exec_op(self.tb, self.c, Some(sds), op)
    }
}

/// Builder for [`Op::Write`].
pub struct WriteBuilder<'s, 't> {
    sess: &'s mut Session<'t>,
    path: String,
    offset: u64,
    len: Option<u64>,
    data: Option<Vec<u8>>,
    mode: AccessMode,
}

impl WriteBuilder<'_, '_> {
    /// Byte offset (default 0).
    pub fn offset(mut self, offset: u64) -> Self {
        self.offset = offset;
        self
    }

    /// Synthetic payload length (ignored when [`WriteBuilder::data`] is
    /// set).
    pub fn len(mut self, len: u64) -> Self {
        self.len = Some(len);
        self
    }

    /// Real bytes to store (sets the length).
    pub fn data(mut self, data: &[u8]) -> Self {
        self.data = Some(data.to_vec());
        self
    }

    /// Access path (default [`AccessMode::Scispace`]).
    pub fn mode(mut self, mode: AccessMode) -> Self {
        self.mode = mode;
        self
    }

    /// The one place the payload-length rule lives: `data` wins, then an
    /// explicit `len`, else 0 (a bare create).
    fn build(path: String, offset: u64, len: Option<u64>, data: Option<Vec<u8>>, mode: AccessMode) -> Op {
        let len = data.as_ref().map(|d| d.len() as u64).or(len).unwrap_or(0);
        Op::Write { path, offset, len, data, mode }
    }

    /// The typed request this builder describes (for batch composition).
    pub fn into_op(self) -> Op {
        Self::build(self.path, self.offset, self.len, self.data, self.mode)
    }

    /// Execute now; returns [`OpResult::Written`].
    pub fn submit(self) -> Result<OpResult, ScispaceError> {
        let WriteBuilder { sess, path, offset, len, data, mode } = self;
        exec_op(sess.tb, sess.c, None, Self::build(path, offset, len, data, mode))
    }
}

/// Builder for [`Op::Read`].
pub struct ReadBuilder<'s, 't> {
    sess: &'s mut Session<'t>,
    path: String,
    offset: u64,
    len: Option<u64>,
    mode: AccessMode,
}

impl ReadBuilder<'_, '_> {
    /// Byte offset (default 0).
    pub fn offset(mut self, offset: u64) -> Self {
        self.offset = offset;
        self
    }

    /// Bytes to read (default: the rest of the file).
    pub fn len(mut self, len: u64) -> Self {
        self.len = Some(len);
        self
    }

    /// Access path (default [`AccessMode::Scispace`]).
    pub fn mode(mut self, mode: AccessMode) -> Self {
        self.mode = mode;
        self
    }

    /// The typed request this builder describes.
    pub fn into_op(self) -> Op {
        Op::Read { path: self.path, offset: self.offset, len: self.len, mode: self.mode }
    }

    /// Execute now; returns [`OpResult::Data`].
    pub fn submit(self) -> Result<OpResult, ScispaceError> {
        let op = Op::Read { path: self.path, offset: self.offset, len: self.len, mode: self.mode };
        exec_op(self.sess.tb, self.sess.c, None, op)
    }
}

/// Builder for [`Op::Ls`].
pub struct LsBuilder<'s, 't> {
    sess: &'s mut Session<'t>,
    prefix: String,
}

impl LsBuilder<'_, '_> {
    /// The typed request this builder describes.
    pub fn into_op(self) -> Op {
        Op::Ls { prefix: self.prefix }
    }

    /// Execute now; returns [`OpResult::Listing`].
    pub fn submit(self) -> Result<OpResult, ScispaceError> {
        let op = Op::Ls { prefix: self.prefix };
        exec_op(self.sess.tb, self.sess.c, None, op)
    }
}

/// Builder for [`Op::Locate`].
pub struct LocateBuilder<'s, 't> {
    sess: &'s mut Session<'t>,
    path: String,
}

impl LocateBuilder<'_, '_> {
    /// The typed request this builder describes.
    pub fn into_op(self) -> Op {
        Op::Locate { path: self.path }
    }

    /// Execute now; returns [`OpResult::Located`].
    pub fn submit(self) -> Result<OpResult, ScispaceError> {
        let op = Op::Locate { path: self.path };
        exec_op(self.sess.tb, self.sess.c, None, op)
    }
}

/// Builder for [`Op::Replicate`].
pub struct ReplicateBuilder<'s, 't, 'f> {
    sess: &'s mut Session<'t>,
    path: String,
    dst_dc: Option<usize>,
    faults: Option<&'f mut FaultInjector>,
}

impl<'s, 't, 'f> ReplicateBuilder<'s, 't, 'f> {
    /// Destination data center (required).
    pub fn to(mut self, dst_dc: usize) -> Self {
        self.dst_dc = Some(dst_dc);
        self
    }

    /// Inject faults into the transfer (single-op submit only; batch
    /// replication runs fault-free).
    pub fn faults(mut self, faults: &'f mut FaultInjector) -> Self {
        self.faults = Some(faults);
        self
    }

    /// The one place the missing-destination rule lives.
    fn require_dst(dst_dc: Option<usize>) -> Result<usize, ScispaceError> {
        dst_dc.ok_or(ScispaceError::Unsupported {
            msg: "replicate needs a destination: .to(dc)".into(),
        })
    }

    /// The typed request this builder describes (drops any fault
    /// injector — batch replication runs fault-free).
    pub fn into_op(self) -> Result<Op, ScispaceError> {
        let dst_dc = Self::require_dst(self.dst_dc)?;
        Ok(Op::Replicate { path: self.path, dst_dc })
    }

    /// Execute now; returns [`OpResult::Replicated`].
    ///
    /// The fault-free case lowers through [`exec_op`] like every other
    /// builder (so the flight recorder wraps it in an `op:replicate`
    /// span); a fault injector is not expressible as a bare [`Op`], so
    /// that case calls the bulk engine directly.
    pub fn submit(self) -> Result<OpResult, ScispaceError> {
        let dst_dc = Self::require_dst(self.dst_dc)?;
        let ReplicateBuilder { sess, path, faults, .. } = self;
        match faults {
            None => exec_op(sess.tb, sess.c, None, Op::Replicate { path, dst_dc }),
            Some(faults) => {
                let rep = sess.tb.bulk_replicate(sess.c, &path, dst_dc, faults)?;
                Ok(OpResult::Replicated(rep))
            }
        }
    }
}

/// Builder for [`Op::Query`].
pub struct QueryBuilder<'s, 't, 'd> {
    sess: &'s mut Session<'t>,
    sds: &'d mut Sds,
    text: String,
    parsed: Option<Query>,
}

impl QueryBuilder<'_, '_, '_> {
    /// The one place the parse rule lives.
    fn build(text: String, parsed: Option<Query>) -> Result<Op, ScispaceError> {
        let query = match parsed {
            Some(q) => q,
            None => Query::parse(&text)
                .map_err(|e| ScispaceError::BadQuery { msg: format!("{e:#}") })?,
        };
        Ok(Op::Query { query })
    }

    /// The typed request this builder describes (parses the text).
    pub fn into_op(self) -> Result<Op, ScispaceError> {
        Self::build(self.text, self.parsed)
    }

    /// Execute now; returns [`OpResult::Hits`].
    pub fn submit(self) -> Result<OpResult, ScispaceError> {
        let QueryBuilder { sess, sds, text, parsed } = self;
        exec_op(sess.tb, sess.c, Some(sds), Self::build(text, parsed)?)
    }
}

/// Builder for [`Op::Tag`].
pub struct TagBuilder<'s, 't, 'd> {
    sess: &'s mut Session<'t>,
    sds: &'d mut Sds,
    path: String,
    attr: String,
    value: Value,
}

impl TagBuilder<'_, '_, '_> {
    /// The typed request this builder describes.
    pub fn into_op(self) -> Op {
        Op::Tag { path: self.path, attr: self.attr, value: self.value }
    }

    /// Execute now; returns [`OpResult::Tagged`].
    pub fn submit(self) -> Result<OpResult, ScispaceError> {
        let op = Op::Tag { path: self.path, attr: self.attr, value: self.value };
        exec_op(self.sess.tb, self.sess.c, Some(self.sds), op)
    }
}

/// Builder for an SDS-indexed SHDF write (not expressible as a bare
/// [`Op`]: it carries a borrowed file, and submit optionally takes a
/// derived-stats provider).
pub struct WriteIndexedBuilder<'s, 't, 'd, 'f> {
    sess: &'s mut Session<'t>,
    sds: &'d mut Sds,
    path: String,
    file: &'f ShdfFile,
    xmode: ExtractionMode,
}

impl WriteIndexedBuilder<'_, '_, '_, '_> {
    /// Extraction mode (default [`ExtractionMode::InlineSync`]).
    pub fn extraction(mut self, mode: ExtractionMode) -> Self {
        self.xmode = mode;
        self
    }

    /// Execute now without derived stats; returns [`OpResult::Written`].
    pub fn submit(self) -> Result<OpResult, ScispaceError> {
        self.submit_stats(None)
    }

    /// Execute now, deriving content statistics with the given provider;
    /// returns [`OpResult::Written`].
    pub fn submit_stats(
        self,
        stats: Option<StatsFn<'_, '_>>,
    ) -> Result<OpResult, ScispaceError> {
        let (finished_at, bytes, transfer) = crate::sds::write_indexed(
            self.sess.tb,
            self.sds,
            self.sess.c,
            &self.path,
            self.file,
            self.xmode,
            stats,
        )?;
        Ok(OpResult::Written { path: self.path, bytes, finished_at, transfer: transfer.map(Box::new) })
    }
}

/// The single lowering of a typed [`Op`] onto the testbed internals —
/// shared by the [`Session`] builders and (for its sequential arm) the
/// batch executor.
///
/// When the flight recorder is on, the whole op is wrapped in an
/// `op:<kind>` span and made the *current* span, so deeper layers (the
/// [`crate::xfer`] flight, for one) parent their own slices under it.
/// With the recorder off this adds no work beyond one branch: spans are
/// never allocated and virtual time is untouched either way.
pub(crate) fn exec_op(
    tb: &mut Testbed,
    c: usize,
    sds: Option<&mut Sds>,
    op: Op,
) -> Result<OpResult, ScispaceError> {
    if c >= tb.collabs.len() {
        return Err(ScispaceError::Unsupported { msg: format!("collaborator {c} not registered") });
    }
    if !tb.env.recording() {
        return exec_op_inner(tb, c, sds, op);
    }
    let t0 = tb.now(c);
    let name = format!("op:{}", op.kind_name());
    let span = tb.env.begin_span(t0, name, None, Some(c));
    let prev = tb.env.set_current_span(Some(span));
    let out = exec_op_inner(tb, c, sds, op);
    tb.env.set_current_span(prev);
    let t1 = tb.now(c);
    tb.env.end_span(span, t1);
    out
}

/// The op lowering itself (no tracing concerns) — see [`exec_op`].
fn exec_op_inner(
    tb: &mut Testbed,
    c: usize,
    sds: Option<&mut Sds>,
    op: Op,
) -> Result<OpResult, ScispaceError> {
    match op {
        Op::Write { path, offset, len, data, mode } => {
            let transfer = tb.write(c, &path, offset, len, data.as_deref(), mode)?;
            Ok(OpResult::Written {
                path,
                bytes: len,
                finished_at: tb.now(c),
                transfer: transfer.map(Box::new),
            })
        }
        Op::Read { path, offset, len, mode } => {
            let len = match len {
                Some(l) => l,
                None => {
                    // whole-file read: size peek is free; the charged
                    // lookup happens inside the read itself. The peek
                    // must resolve the same copy the read will use:
                    // native (LW) access reads the home-DC namespace,
                    // workspace modes go through the metadata plane.
                    let located = match mode {
                        AccessMode::ScispaceLw => {
                            let home = tb.collabs[c].dc;
                            match tb.dcs[home].fs.get(&path) {
                                Some(e) => Some((
                                    home,
                                    e.obj.ok_or_else(|| ScispaceError::IsDirectory {
                                        path: path.clone(),
                                    })?,
                                )),
                                None => None,
                            }
                        }
                        _ => tb.locate(&path),
                    };
                    let (dc, obj) = match located {
                        Some(hit) => hit,
                        None => {
                            // delegate the failure to the read itself, so
                            // a missing path pays exactly the same
                            // charges (per-DC locate fallback + stats) and
                            // returns the same typed error as an
                            // explicit-length read of it
                            tb.read(c, &path, offset, 0, mode)?;
                            return Err(ScispaceError::NoSuchFile { path });
                        }
                    };
                    match tb.dcs[dc].store.len(obj) {
                        Some(total) => total.saturating_sub(offset),
                        None => {
                            // namespace entry with no backing object: a
                            // vanished file, not a zero-byte one — same
                            // delegated charges + typed error as the
                            // locate miss above
                            tb.read(c, &path, offset, 0, mode)?;
                            return Err(ScispaceError::NoSuchFile { path });
                        }
                    }
                }
            };
            let (bytes, transfer) = tb.read_traced(c, &path, offset, len, mode)?;
            Ok(OpResult::Data { bytes, finished_at: tb.now(c), transfer: transfer.map(Box::new) })
        }
        Op::Ls { prefix } => {
            let entries = tb.ls(c, &prefix);
            Ok(OpResult::Listing { entries, finished_at: tb.now(c) })
        }
        Op::Locate { path } => {
            let (dc, obj) = tb
                .locate_for(c, &path)
                .ok_or_else(|| ScispaceError::NoSuchFile { path: path.clone() })?;
            let size = tb.dcs[dc].store.len(obj).unwrap_or(0);
            Ok(OpResult::Located { dc, size, finished_at: tb.now(c) })
        }
        Op::Replicate { path, dst_dc } => {
            let rep = tb.bulk_replicate(c, &path, dst_dc, &mut FaultInjector::none())?;
            Ok(OpResult::Replicated(rep))
        }
        Op::Query { query } => {
            let sds = sds.ok_or(ScispaceError::Unsupported {
                msg: "query needs a discovery service (Session::query / run_batch_with_sds)".into(),
            })?;
            let (files, latency_s) = crate::sds::run_query(tb, sds, c, &query)?;
            Ok(OpResult::Hits { files, latency_s, finished_at: tb.now(c) })
        }
        Op::Tag { path, attr, value } => {
            let sds = sds.ok_or(ScispaceError::Unsupported {
                msg: "tag needs a discovery service (Session::tag / run_batch_with_sds)".into(),
            })?;
            crate::sds::tag(tb, sds, c, &path, &attr, value)?;
            Ok(OpResult::Tagged { finished_at: tb.now(c) })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sds::{Sds, SdsConfig};

    fn bed() -> Testbed {
        let mut tb = Testbed::paper_default();
        tb.register("c0", 0);
        tb.register("c1", 1);
        tb
    }

    /// Equivalence regression (the PR-2-style property): every single-op
    /// Session call lands on the exact same completion time as the
    /// legacy positional-argument path — bit for bit.
    #[test]
    fn session_single_ops_bit_identical_to_legacy_path() {
        let mut a = bed(); // legacy positional calls
        let mut b = bed(); // Session builder calls
        let bits = |x: &Testbed, c: usize| x.now(c).to_bits();

        // create-write with real bytes
        a.write(0, "/eq/x.dat", 0, 5, Some(b"hello"), AccessMode::Scispace).unwrap();
        b.session(0).write("/eq/x.dat").data(b"hello").submit().unwrap();
        assert_eq!(bits(&a, 0), bits(&b, 0), "small write");

        // bulk synthetic write (striped-engine path)
        a.write(0, "/eq/big.dat", 0, 16 << 20, None, AccessMode::Scispace).unwrap();
        b.session(0).write("/eq/big.dat").len(16 << 20).submit().unwrap();
        assert_eq!(bits(&a, 0), bits(&b, 0), "bulk write");

        // remote bulk read (WAN + striped engine)
        a.read(1, "/eq/big.dat", 0, 16 << 20, AccessMode::Scispace).unwrap();
        b.session(1).read("/eq/big.dat").len(16 << 20).submit().unwrap();
        assert_eq!(bits(&a, 1), bits(&b, 1), "bulk read");

        // whole-file read with builder-resolved length
        a.read(1, "/eq/x.dat", 0, 5, AccessMode::Scispace).unwrap();
        b.session(1).read("/eq/x.dat").submit().unwrap();
        assert_eq!(bits(&a, 1), bits(&b, 1), "whole-file read");

        // listing fan-out
        a.ls(1, "/eq");
        b.session(1).ls("/eq").submit().unwrap();
        assert_eq!(bits(&a, 1), bits(&b, 1), "ls");

        // charged locate
        a.locate_for(0, "/eq/x.dat").unwrap();
        b.session(0).locate("/eq/x.dat").submit().unwrap();
        assert_eq!(bits(&a, 0), bits(&b, 0), "locate");

        // replication data plane
        a.bulk_replicate(0, "/eq/big.dat", 1, &mut FaultInjector::none()).unwrap();
        b.session(0).replicate("/eq/big.dat").to(1).submit().unwrap();
        assert_eq!(bits(&a, 0), bits(&b, 0), "replicate");

        // SDS tag + query
        let mut sa = Sds::new(a.dtns.len(), SdsConfig::default());
        let mut sb = Sds::new(b.dtns.len(), SdsConfig::default());
        crate::sds::tag(&mut a, &mut sa, 0, "/eq/x.dat", "k", Value::Int(1)).unwrap();
        b.session(0).tag(&mut sb, "/eq/x.dat", "k", Value::Int(1)).submit().unwrap();
        assert_eq!(bits(&a, 0), bits(&b, 0), "tag");
        let q = Query::parse("k = 1").unwrap();
        crate::sds::run_query(&mut a, &mut sa, 1, &q).unwrap();
        let hits =
            b.session(1).query_parsed(&mut sb, q).submit().unwrap().files().unwrap();
        assert_eq!(hits, vec!["/eq/x.dat".to_string()]);
        assert_eq!(bits(&a, 1), bits(&b, 1), "query");
    }

    #[test]
    fn typed_errors_replace_stringly_failures() {
        let mut tb = bed();
        let mut sess = tb.session(0);
        match sess.read("/nope").submit() {
            Err(ScispaceError::NoSuchFile { path }) => assert_eq!(path, "/nope"),
            other => panic!("expected NoSuchFile, got {other:?}"),
        }
        sess.write("/e/f.dat").data(b"x").submit().unwrap();
        match sess.replicate("/e/f.dat").to(9).submit() {
            Err(ScispaceError::NoSuchDc { dc }) => assert_eq!(dc, 9),
            other => panic!("expected NoSuchDc, got {other:?}"),
        }
        let home = tb.collabs[0].dc;
        match tb.session(0).replicate("/e/f.dat").to(home).submit() {
            Err(ScispaceError::AlreadyReplicated { dc, .. }) => assert_eq!(dc, home),
            other => panic!("expected AlreadyReplicated, got {other:?}"),
        }
        match tb.session(0).replicate("/e/f.dat").submit() {
            Err(ScispaceError::Unsupported { .. }) => {}
            other => panic!("expected Unsupported (missing .to), got {other:?}"),
        }
        // SDS ops without a discovery service attached are typed too
        match tb.session(0).submit(Op::Query { query: Query::parse("a = 1").unwrap() }) {
            Err(ScispaceError::Unsupported { .. }) => {}
            other => panic!("expected Unsupported, got {other:?}"),
        }
    }

    #[test]
    fn builders_compose_into_batch_ops() {
        let mut tb = bed();
        let mut sess = tb.session(0);
        let w = sess.write("/b/a.dat").offset(8).len(16).into_op();
        assert_eq!(
            w,
            Op::Write {
                path: "/b/a.dat".into(),
                offset: 8,
                len: 16,
                data: None,
                mode: AccessMode::Scispace
            }
        );
        let r = sess.read("/b/a.dat").mode(AccessMode::Baseline).into_op();
        assert_eq!(
            r,
            Op::Read { path: "/b/a.dat".into(), offset: 0, len: None, mode: AccessMode::Baseline }
        );
        let rep = sess.replicate("/b/a.dat").to(1).into_op().unwrap();
        assert_eq!(rep, Op::Replicate { path: "/b/a.dat".into(), dst_dc: 1 });
        assert!(sess.replicate("/b/a.dat").into_op().is_err(), "destination required");
    }
}
