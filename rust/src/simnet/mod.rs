//! Network model: links between collaborator machines, DTNs and data
//! centers, carried on the discrete-event core's processor-sharing
//! links ([`crate::engine`]).
//!
//! The paper's testbed connects two data centers over InfiniBand EDR
//! (100 Gb/s) and deliberately provisions the inter-DC network *faster*
//! than each center's Lustre bandwidth ("the network bandwidth between the
//! data centers is higher than the PFS bandwidth of each data center", to
//! emulate ESnet-class terabit links). [`NetConfig::paper_default`]
//! encodes that relationship; benches scale it.
//!
//! Every payload movement is a *flow* over the hop sequence returned by
//! [`Network::path`]: it serializes hop-by-hop, sharing each link's
//! bandwidth with whatever other flows ride it at the same virtual time.
//! [`Network::route`] and [`Network::send`] are the blocking
//! conveniences (start one flow, drain the queue until it completes);
//! schedulers that need concurrent flows to genuinely share the wire
//! start their flows first and drain the engine afterwards.

use crate::engine::{Engine, LinkId};

/// Aggregated live state of one `src -> dst` path (see
/// [`Network::path_load`]): how busy and how lossy the hops are right
/// now. Ordering a candidate set by `(active_flows, losses,
/// retransmit_bytes)` ranks sources least-loaded-then-least-lossy;
/// [`PathLoad::rank_key`] is that lexicographic key.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PathLoad {
    /// Engine flows in service summed over the path's hops.
    pub active_flows: usize,
    /// Congestion losses synthesized on the hops (lifetime totals).
    pub losses: u64,
    /// Bytes those losses re-queued for retransmission.
    pub retransmit_bytes: u64,
    /// Peak bulk-transfer registrations across the hops (this
    /// network's own [`Network::begin_transfer`] accounting).
    pub registered_transfers: u32,
}

impl PathLoad {
    /// Lexicographic least-loaded-then-least-lossy comparison key.
    pub fn rank_key(&self) -> (usize, u32, u64, u64) {
        (self.active_flows, self.registered_transfers, self.losses, self.retransmit_bytes)
    }
}

/// A directed network link (shared medium => one engine link both ways).
#[derive(Debug, Clone, Copy)]
pub struct Link {
    /// Underlying processor-sharing link in the engine.
    pub res: LinkId,
    /// One-way propagation latency (seconds), paid per message. Mirrors
    /// the engine link's latency (kept here for ack-path math).
    pub latency_s: f64,
}

/// One tier's worth of link parameters in a federated topology: the
/// site LANs, the regional aggregation links and the shared backbone
/// WAN each get their own class. A [`NetConfig`] is exactly two of
/// these (WAN + LAN); [`Network::build_federation`] takes three.
#[derive(Debug, Clone, Copy)]
pub struct LinkClass {
    /// Bandwidth, bytes/s.
    pub bw: f64,
    /// One-way propagation latency, seconds.
    pub latency_s: f64,
    /// Sustained-overload interval before the link synthesizes
    /// congestion loss for windowed flows (`INFINITY` = lossless).
    pub loss_detect_s: f64,
}

impl LinkClass {
    /// A lossless link class.
    pub fn lossless(bw: f64, latency_s: f64) -> Self {
        LinkClass { bw, latency_s, loss_detect_s: f64::INFINITY }
    }

    fn build(&self, env: &mut Engine, name: &str) -> Link {
        let res = env.add_link(name, self.bw, self.latency_s);
        if self.loss_detect_s.is_finite() {
            env.set_link_loss_detect(res, self.loss_detect_s);
        }
        Link { res, latency_s: self.latency_s }
    }
}

/// Network configuration for a collaboration testbed.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Inter-data-center bandwidth, bytes/s.
    pub wan_bw: f64,
    /// Inter-data-center one-way latency, seconds.
    pub wan_latency_s: f64,
    /// Intra-data-center (collaborator<->DTN / DTN<->OSS) bandwidth, bytes/s.
    pub lan_bw: f64,
    /// Intra-DC one-way latency, seconds.
    pub lan_latency_s: f64,
    /// The WAN loss knob: sustained-overload interval before the WAN
    /// synthesizes congestion loss for windowed flows
    /// ([`crate::engine::CcConfig`]). `INFINITY` (the default) keeps
    /// the WAN lossless — windowed flows then behave exactly like
    /// plain processor-sharing flows.
    pub wan_loss_detect_s: f64,
    /// Same knob for the intra-DC fabrics (lossless by default; real
    /// datacenter fabrics are flow-controlled, not drop-based).
    pub lan_loss_detect_s: f64,
}

impl NetConfig {
    /// Paper testbed: IB EDR 100 Gb/s (12.5 GB/s) WAN, geo latency kept
    /// small as in the paper's same-room emulation; LAN at the same fabric
    /// speed. The Lustre config (see `simfs`) is set *below* this so the
    /// network is never the bottleneck, as the paper configures.
    pub fn paper_default() -> Self {
        NetConfig {
            wan_bw: 12.5e9,
            wan_latency_s: 50e-6,
            lan_bw: 12.5e9,
            lan_latency_s: 20e-6,
            wan_loss_detect_s: f64::INFINITY,
            lan_loss_detect_s: f64::INFINITY,
        }
    }

    /// A genuinely geo-distributed deployment (the regime the paper's
    /// same-room emulation abstracts away): a 10 Gb/s WAN with a 25 ms
    /// one-way latency that *is* the bottleneck, congestion-managed so
    /// windowed flows see synthesized loss under sustained overload.
    /// The LANs stay at fabric speed and lossless. This is the network
    /// the over-striping sweeps (`fig_xfer_streams_cc`) run on.
    pub fn geo_default() -> Self {
        NetConfig {
            wan_bw: 1.25e9,
            wan_latency_s: 25e-3,
            lan_bw: 12.5e9,
            lan_latency_s: 20e-6,
            wan_loss_detect_s: 20e-3,
            lan_loss_detect_s: f64::INFINITY,
        }
    }
}

/// The instantiated network: one WAN link + per-DC LAN links (plus,
/// on federated beds, per-region aggregation links), plus
/// multi-transfer contention accounting (how many bulk transfers are
/// concurrently riding each link, and the peak seen).
#[derive(Debug, Clone)]
pub struct Network {
    /// DC-to-DC backbone link.
    pub wan: Link,
    /// Per data center local fabric.
    pub lans: Vec<Link>,
    /// Per-region aggregation links (federated beds only; empty on the
    /// classic flat beds, which keeps every path identical to before).
    pub regionals: Vec<Link>,
    /// Region assignment per DC (`None` = attached straight to the
    /// backbone, the flat-bed behaviour for every DC).
    region_of: Vec<Option<usize>>,
    /// Concurrent bulk transfers per link
    /// (slot 0 = WAN, 1+i = LAN i, 1+n_dcs+r = regional r).
    active: Vec<u32>,
    /// Peak concurrent bulk transfers per link.
    peak: Vec<u32>,
    /// Unbalanced `end_transfer` calls observed (an end without its
    /// begin). Debug builds also assert; release builds used to mask
    /// the bug behind the saturating release — this counter surfaces
    /// it, sampled into the metrics registry as
    /// `sim_invariant_violations` by `Testbed::sample_metrics`.
    invariant_violations: u64,
}

impl Network {
    /// Build the network links inside `env` for `n_dcs` data centers.
    pub fn build(env: &mut Engine, cfg: &NetConfig, n_dcs: usize) -> Network {
        let wan = Link {
            res: env.add_link("net.wan", cfg.wan_bw, cfg.wan_latency_s),
            latency_s: cfg.wan_latency_s,
        };
        if cfg.wan_loss_detect_s.is_finite() {
            env.set_link_loss_detect(wan.res, cfg.wan_loss_detect_s);
        }
        let lans: Vec<Link> = (0..n_dcs)
            .map(|i| {
                let res = env.add_link(&format!("net.lan{i}"), cfg.lan_bw, cfg.lan_latency_s);
                if cfg.lan_loss_detect_s.is_finite() {
                    env.set_link_loss_detect(res, cfg.lan_loss_detect_s);
                }
                Link { res, latency_s: cfg.lan_latency_s }
            })
            .collect();
        let slots = 1 + lans.len();
        Network {
            wan,
            lans,
            regionals: Vec::new(),
            region_of: vec![None; n_dcs],
            active: vec![0; slots],
            peak: vec![0; slots],
            invariant_violations: 0,
        }
    }

    /// Build a federated network: a shared backbone WAN, one LAN per
    /// site, and one aggregation link per region. `region_of[dc]`
    /// assigns each site to a region (or `None` for direct backbone
    /// attachment — typically the origin sites). Link creation order
    /// (`net.wan`, then `net.lan{i}`, then `net.regional{r}`) matches
    /// [`Network::build`], so a federation with no regions and the
    /// classes taken from a [`NetConfig`] is bit-identical to the
    /// classic flat bed.
    pub fn build_federation(
        env: &mut Engine,
        backbone: &LinkClass,
        site_lan: &LinkClass,
        regional: &LinkClass,
        region_of: Vec<Option<usize>>,
    ) -> Network {
        let wan = backbone.build(env, "net.wan");
        let lans: Vec<Link> = (0..region_of.len())
            .map(|i| site_lan.build(env, &format!("net.lan{i}")))
            .collect();
        let n_regions = region_of.iter().flatten().map(|r| r + 1).max().unwrap_or(0);
        let regionals: Vec<Link> =
            (0..n_regions).map(|r| regional.build(env, &format!("net.regional{r}"))).collect();
        let slots = 1 + lans.len() + regionals.len();
        Network {
            wan,
            lans,
            regionals,
            region_of,
            active: vec![0; slots],
            peak: vec![0; slots],
            invariant_violations: 0,
        }
    }

    /// Region a DC is attached to (`None` on flat beds or for
    /// backbone-attached origin sites).
    pub fn region_of(&self, dc: usize) -> Option<usize> {
        self.region_of.get(dc).copied().flatten()
    }

    /// Send `bytes` over `link` starting at `now`, blocking to
    /// completion; returns the arrival time (serialization + latency).
    pub fn send(env: &mut Engine, link: Link, now: f64, bytes: u64) -> f64 {
        let f = env.start_flow(&[link.res], bytes, now, 1.0);
        let t = env.completion(f);
        // blocking helper: the flow id never escapes, so its slot can
        // go straight back to the engine's free list
        env.retire_flow(f);
        t
    }

    /// Path cost helper: collaborator in `src_dc` touching storage in
    /// `dst_dc` crosses its LAN, then (if different DC) the WAN, then the
    /// remote LAN — one flow over the whole hop sequence, drained to
    /// completion. Returns the data arrival time.
    pub fn route(
        &self,
        env: &mut Engine,
        src_dc: usize,
        dst_dc: usize,
        now: f64,
        bytes: u64,
    ) -> f64 {
        let path = self.flow_path(src_dc, dst_dc);
        let f = env.start_flow(&path, bytes, now, 1.0);
        let t = env.completion(f);
        env.retire_flow(f);
        t
    }

    /// The single source of hop truth: accounting slots a `src -> dst`
    /// payload traverses, in order (0 = WAN, 1+i = LAN i,
    /// 1+n_dcs+r = regional r). `route`, `path` and the contention
    /// counters all derive from this. On flat beds (no regions) this
    /// is exactly the historical `[lan, wan, lan]`; on federated beds
    /// a payload climbs through its source region's aggregation link,
    /// rides the backbone only when the endpoints sit in different
    /// regions, and descends through the destination region's link.
    fn hop_slots(&self, src_dc: usize, dst_dc: usize) -> Vec<usize> {
        if src_dc == dst_dc {
            return vec![1 + src_dc];
        }
        let regional_slot = |r: usize| 1 + self.lans.len() + r;
        let (src_r, dst_r) = (self.region_of(src_dc), self.region_of(dst_dc));
        let mut slots = vec![1 + src_dc];
        match (src_r, dst_r) {
            (Some(a), Some(b)) if a == b => slots.push(regional_slot(a)),
            _ => {
                if let Some(a) = src_r {
                    slots.push(regional_slot(a));
                }
                slots.push(0);
                if let Some(b) = dst_r {
                    slots.push(regional_slot(b));
                }
            }
        }
        slots.push(1 + dst_dc);
        slots
    }

    /// The link occupying accounting slot `s` (see [`Network::hop_slots`]).
    fn slot_link(&self, s: usize) -> Link {
        if s == 0 {
            self.wan
        } else if s <= self.lans.len() {
            self.lans[s - 1]
        } else {
            self.regionals[s - 1 - self.lans.len()]
        }
    }

    /// The ordered link sequence a `src_dc -> dst_dc` payload traverses
    /// (same hops as [`Network::route`]). Used by the `xfer` engine to
    /// drive each chunk over the path explicitly.
    pub fn path(&self, src_dc: usize, dst_dc: usize) -> Vec<Link> {
        self.hop_slots(src_dc, dst_dc).into_iter().map(|s| self.slot_link(s)).collect()
    }

    /// The same hop sequence as engine link ids, ready for
    /// [`Engine::start_flow`].
    pub fn flow_path(&self, src_dc: usize, dst_dc: usize) -> Vec<LinkId> {
        self.hop_slots(src_dc, dst_dc).into_iter().map(|s| self.slot_link(s).res).collect()
    }

    /// Round-trip time of the `src_dc -> dst_dc` path: twice the sum of
    /// its per-hop one-way latencies. This is the RTT a windowed flow's
    /// `window / rtt` cap is computed against.
    pub fn path_rtt(&self, src_dc: usize, dst_dc: usize) -> f64 {
        2.0 * self.path(src_dc, dst_dc).iter().map(|l| l.latency_s).sum::<f64>()
    }

    /// Live load/loss summary of the `src_dc -> dst_dc` path, aggregated
    /// over its hops from the engine's link state
    /// ([`Engine::link_state`]) plus this network's own transfer
    /// registrations. This is the signal a loss/load-aware replica
    /// sourcing policy ranks candidate source DCs by
    /// (`metadata::replication::SourcePolicy::LinkAware`).
    pub fn path_load(&self, env: &Engine, src_dc: usize, dst_dc: usize) -> PathLoad {
        let mut load = PathLoad::default();
        for s in self.hop_slots(src_dc, dst_dc) {
            let st = env.link_state(self.slot_link(s).res);
            load.active_flows += st.active_flows;
            load.losses += st.total_losses;
            load.retransmit_bytes += st.total_retransmit_bytes;
            load.registered_transfers = load.registered_transfers.max(self.active[s]);
        }
        load
    }

    /// Register a bulk transfer on its path (contention accounting).
    pub fn begin_transfer(&mut self, src_dc: usize, dst_dc: usize) {
        for s in self.hop_slots(src_dc, dst_dc) {
            self.active[s] += 1;
            self.peak[s] = self.peak[s].max(self.active[s]);
        }
    }

    /// Deregister a completed bulk transfer. Release semantics stay
    /// saturating in release builds, but an unbalanced `end_transfer`
    /// (double-end, or an end without its begin) is a caller bug that
    /// used to be silently masked — debug builds assert, and *every*
    /// build counts it in [`Network::invariant_violations`] so
    /// release-mode runs surface it through the metrics registry
    /// instead of silently passing.
    pub fn end_transfer(&mut self, src_dc: usize, dst_dc: usize) {
        for s in self.hop_slots(src_dc, dst_dc) {
            if self.active[s] == 0 {
                self.invariant_violations += 1;
            }
            debug_assert!(
                self.active[s] > 0,
                "end_transfer without a matching begin_transfer on slot {s} \
                 (src_dc={src_dc}, dst_dc={dst_dc})"
            );
            self.active[s] = self.active[s].saturating_sub(1);
        }
    }

    /// Unbalanced `end_transfer` calls observed so far (0 in a healthy
    /// run; see [`Network::end_transfer`]).
    pub fn invariant_violations(&self) -> u64 {
        self.invariant_violations
    }

    /// Bulk transfers currently riding the WAN.
    pub fn wan_active(&self) -> u32 {
        self.active[0]
    }

    /// Peak concurrent bulk transfers seen on the WAN.
    pub fn wan_peak(&self) -> u32 {
        self.peak[0]
    }

    /// Bulk transfers currently riding LAN `dc`.
    pub fn lan_active(&self, dc: usize) -> u32 {
        self.active[1 + dc]
    }

    /// Peak concurrent bulk transfers seen on LAN `dc`.
    pub fn lan_peak(&self, dc: usize) -> u32 {
        self.peak[1 + dc]
    }

    /// Congestion losses synthesized on the WAN (next to
    /// [`Network::wan_peak`] in the contention accounting; always 0
    /// unless the WAN loss knob is armed).
    pub fn wan_losses(&self, env: &Engine) -> u64 {
        env.link(self.wan.res).total_losses
    }

    /// Bytes those WAN losses re-queued for retransmission.
    pub fn wan_retransmit_bytes(&self, env: &Engine) -> u64 {
        env.link(self.wan.res).total_retransmit_bytes
    }

    /// Congestion losses synthesized on LAN `dc`.
    pub fn lan_losses(&self, env: &Engine, dc: usize) -> u64 {
        env.link(self.lans[dc].res).total_losses
    }

    /// Bytes LAN `dc`'s losses re-queued for retransmission.
    pub fn lan_retransmit_bytes(&self, env: &Engine, dc: usize) -> u64 {
        env.link(self.lans[dc].res).total_retransmit_bytes
    }

    /// Clear contention counters (between experiment iterations).
    pub fn reset_contention(&mut self) {
        self.active.iter_mut().for_each(|a| *a = 0);
        self.peak.iter_mut().for_each(|p| *p = 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Engine, Network) {
        let mut env = Engine::new();
        let net = Network::build(&mut env, &NetConfig::paper_default(), 2);
        (env, net)
    }

    #[test]
    fn local_route_skips_wan() {
        let (mut env, net) = setup();
        let t = net.route(&mut env, 0, 0, 0.0, 1 << 20);
        assert_eq!(env.link(net.wan.res).total_bytes, 0);
        assert!(t > 0.0);
    }

    #[test]
    fn remote_route_crosses_wan_once() {
        let (mut env, net) = setup();
        let _ = net.route(&mut env, 0, 1, 0.0, 1 << 20);
        assert_eq!(env.link(net.wan.res).total_bytes, 1 << 20);
        assert_eq!(env.link(net.lans[0].res).total_bytes, 1 << 20);
        assert_eq!(env.link(net.lans[1].res).total_bytes, 1 << 20);
    }

    #[test]
    fn remote_slower_than_local() {
        let (mut env, net) = setup();
        let tl = net.route(&mut env, 0, 0, 0.0, 1 << 24);
        env.reset();
        let tr = net.route(&mut env, 0, 1, 0.0, 1 << 24);
        assert!(tr > tl, "remote {tr} <= local {tl}");
    }

    #[test]
    fn wan_faster_than_typical_pfs() {
        // Invariant the paper sets: WAN bandwidth above PFS aggregate.
        let cfg = NetConfig::paper_default();
        let pfs_aggregate = 2.0 * 2.2e9; // see simfs::LustreConfig::paper_default
        assert!(cfg.wan_bw > pfs_aggregate);
    }

    #[test]
    fn path_matches_route_hops() {
        let (mut env, net) = setup();
        assert_eq!(net.path(0, 0).len(), 1);
        let p = net.path(0, 1);
        assert_eq!(p.len(), 3);
        assert_eq!(p[1].res, net.wan.res);
        // driving the path by hand charges the same links as route()
        let bytes = 1 << 20;
        let mut t = 0.0;
        for link in &p {
            t = Network::send(&mut env, *link, t, bytes);
        }
        assert!(t > 0.0);
        assert_eq!(env.link(net.wan.res).total_bytes, bytes);
        assert_eq!(env.link(net.lans[0].res).total_bytes, bytes);
        assert_eq!(env.link(net.lans[1].res).total_bytes, bytes);
    }

    #[test]
    fn flow_path_mirrors_path() {
        let (_env, net) = setup();
        for (src, dst) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
            let by_link: Vec<_> = net.path(src, dst).iter().map(|l| l.res).collect();
            assert_eq!(by_link, net.flow_path(src, dst));
        }
    }

    #[test]
    fn concurrent_equal_flows_share_the_wan() {
        // Tentpole acceptance: two equal concurrent WAN flows each
        // finish in ~2x the solo time — processor sharing, not
        // serialize-behind-the-horizon.
        let bytes = 1u64 << 30;
        let (mut env, net) = setup();
        let solo = {
            let f = env.start_flow(&net.flow_path(0, 1), bytes, 0.0, 1.0);
            env.completion(f)
        };
        let (mut env, net) = setup();
        let path = net.flow_path(0, 1);
        let f1 = env.start_flow(&path, bytes, 0.0, 1.0);
        let f2 = env.start_flow(&path, bytes, 0.0, 1.0);
        let t1 = env.completion(f1);
        let t2 = env.completion(f2);
        assert!((t1 - t2).abs() < 1e-6, "equal flows must finish together: {t1} vs {t2}");
        let ratio = t1.max(t2) / solo;
        assert!(
            (1.8..2.05).contains(&ratio),
            "shared wire must halve bandwidth (ratio ~2), not serialize: ratio={ratio}"
        );
    }

    #[test]
    fn path_rtt_sums_hops_both_ways() {
        let (_env, net) = setup();
        let cfg = NetConfig::paper_default();
        let local = net.path_rtt(0, 0);
        assert!((local - 2.0 * cfg.lan_latency_s).abs() < 1e-12);
        let remote = net.path_rtt(0, 1);
        assert!(
            (remote - 2.0 * (2.0 * cfg.lan_latency_s + cfg.wan_latency_s)).abs() < 1e-12,
            "remote rtt {remote}"
        );
    }

    #[test]
    fn default_wan_is_lossless_for_windowed_flows() {
        use crate::engine::CcConfig;
        let (mut env, net) = setup();
        let path = net.flow_path(0, 1);
        // oversubscribe wildly; without the loss knob nothing happens
        let flows: Vec<_> = (0..4)
            .map(|_| env.start_windowed_flow(&path, 64 << 20, 0.0, 1.0, &CcConfig::default()))
            .collect();
        for f in flows {
            env.completion(f);
        }
        assert_eq!(net.wan_losses(&env), 0);
        assert_eq!(net.wan_retransmit_bytes(&env), 0);
    }

    #[test]
    fn geo_wan_synthesizes_loss_under_oversubscription() {
        use crate::engine::CcConfig;
        let mut env = Engine::new();
        let net = Network::build(&mut env, &NetConfig::geo_default(), 2);
        let path = net.flow_path(0, 1);
        // 16 windowed flows demand far more than the 1.25 GB/s WAN
        let flows: Vec<_> = (0..16)
            .map(|_| env.start_windowed_flow(&path, 16 << 20, 0.0, 1.0, &CcConfig::default()))
            .collect();
        for f in flows {
            env.completion(f);
        }
        assert!(net.wan_losses(&env) > 0, "sustained WAN overload must synthesize loss");
        assert!(net.wan_retransmit_bytes(&env) > 0);
        assert_eq!(net.lan_losses(&env, 0), 0, "the lossless LANs never drop");
        assert_eq!(net.lan_losses(&env, 1), 0);
    }

    #[test]
    fn contention_accounting_tracks_active_and_peak() {
        let (_env, mut net) = setup();
        net.begin_transfer(0, 1);
        net.begin_transfer(0, 1);
        net.begin_transfer(1, 1); // LAN-only
        assert_eq!(net.wan_active(), 2);
        assert_eq!(net.lan_active(1), 3);
        net.end_transfer(0, 1);
        assert_eq!(net.wan_active(), 1);
        net.end_transfer(0, 1);
        net.end_transfer(1, 1);
        assert_eq!(net.wan_active(), 0);
        assert_eq!(net.wan_peak(), 2);
        assert_eq!(net.lan_peak(1), 3);
        net.reset_contention();
        assert_eq!(net.wan_peak(), 0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "end_transfer without a matching begin_transfer")]
    fn unbalanced_end_transfer_asserts_in_debug() {
        let (_env, mut net) = setup();
        net.begin_transfer(0, 1);
        net.end_transfer(0, 1);
        net.end_transfer(0, 1); // double-end: a caller bug, now loud
    }

    #[test]
    fn balanced_transfers_never_count_violations() {
        let (_env, mut net) = setup();
        net.begin_transfer(0, 1);
        net.begin_transfer(1, 1);
        net.end_transfer(1, 1);
        net.end_transfer(0, 1);
        assert_eq!(net.invariant_violations(), 0);
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn unbalanced_end_transfer_counts_in_release() {
        // Release builds don't assert — the saturating release used to
        // mask the bug entirely. The violation counter surfaces it.
        let (_env, mut net) = setup();
        net.begin_transfer(0, 1);
        net.end_transfer(0, 1);
        net.end_transfer(0, 1); // double-end: one violation per hop slot
        assert_eq!(net.invariant_violations(), 3, "cross-DC path has 3 slots");
        assert_eq!(net.wan_active(), 0, "saturating release still holds");
    }

    #[test]
    fn federation_with_no_regions_matches_classic_build() {
        let cfg = NetConfig::paper_default();
        let wan = LinkClass {
            bw: cfg.wan_bw,
            latency_s: cfg.wan_latency_s,
            loss_detect_s: cfg.wan_loss_detect_s,
        };
        let lan = LinkClass {
            bw: cfg.lan_bw,
            latency_s: cfg.lan_latency_s,
            loss_detect_s: cfg.lan_loss_detect_s,
        };
        let mut env_a = Engine::new();
        let net_a = Network::build(&mut env_a, &cfg, 3);
        let mut env_b = Engine::new();
        let net_b = Network::build_federation(&mut env_b, &wan, &lan, &lan, vec![None; 3]);
        assert!(net_b.regionals.is_empty());
        for src in 0..3 {
            for dst in 0..3 {
                assert_eq!(net_a.flow_path(src, dst), net_b.flow_path(src, dst));
                let ta = net_a.route(&mut env_a, src, dst, 0.0, 1 << 20);
                let tb = net_b.route(&mut env_b, src, dst, 0.0, 1 << 20);
                assert_eq!(ta.to_bits(), tb.to_bits(), "{src}->{dst}");
                env_a.reset();
                env_b.reset();
            }
        }
    }

    #[test]
    fn federation_paths_climb_through_regions() {
        let mut env = Engine::new();
        let bb = LinkClass::lossless(1.25e9, 25e-3);
        let reg = LinkClass::lossless(2.5e9, 5e-3);
        let lan = LinkClass::lossless(12.5e9, 20e-6);
        // site 0 = origin on the backbone, sites 1-2 in region 0, site 3 in region 1
        let net = Network::build_federation(
            &mut env,
            &bb,
            &lan,
            &reg,
            vec![None, Some(0), Some(0), Some(1)],
        );
        assert_eq!(net.regionals.len(), 2);
        let ids = |src: usize, dst: usize| net.flow_path(src, dst);
        // intra-region traffic stays off the backbone
        assert_eq!(ids(1, 2), vec![net.lans[1].res, net.regionals[0].res, net.lans[2].res]);
        // cross-region climbs src regional, backbone, dst regional
        assert_eq!(
            ids(1, 3),
            vec![
                net.lans[1].res,
                net.regionals[0].res,
                net.wan.res,
                net.regionals[1].res,
                net.lans[3].res
            ]
        );
        // origin <-> cache site crosses exactly one regional
        assert_eq!(
            ids(0, 2),
            vec![net.lans[0].res, net.wan.res, net.regionals[0].res, net.lans[2].res]
        );
        // same-site stays on the LAN
        assert_eq!(ids(3, 3), vec![net.lans[3].res]);
        // rtt follows the hop sequence
        let rtt = net.path_rtt(1, 3);
        assert!((rtt - 2.0 * (20e-6 + 5e-3 + 25e-3 + 5e-3 + 20e-6)).abs() < 1e-12, "rtt {rtt}");
        // contention accounting covers regional slots too
        let mut net = net;
        net.begin_transfer(1, 3);
        assert_eq!(net.wan_active(), 1);
        net.end_transfer(1, 3);
        assert_eq!(net.invariant_violations(), 0);
    }

    #[test]
    fn prop_bytes_conserved_across_routes_and_striped_sends() {
        // Satellite invariant: bytes charged to each link equal bytes
        // offered, across any interleaving of monolithic route() calls
        // and chunk-striped xfer transfers (including retried chunks).
        use crate::util::prop;
        use crate::xfer::{FaultInjector, Priority, TransferRequest, XferConfig, XferEngine};
        prop::check(24, |rng| {
            let mut env = Engine::new();
            let mut net = Network::build(&mut env, &NetConfig::paper_default(), 2);
            // expected per-link byte totals: [wan, lan0, lan1]
            let ids = [net.wan.res, net.lans[0].res, net.lans[1].res];
            let mut expect = [0u64; 3];
            let mut offer = |expect: &mut [u64; 3], src: usize, dst: usize, b: u64| {
                expect[1 + src] += b;
                if src != dst {
                    expect[0] += b;
                    expect[1 + dst] += b;
                }
            };
            for i in 0..rng.range(2, 9) {
                let src = rng.range(0, 2);
                let dst = rng.range(0, 2);
                if rng.chance(0.4) {
                    let b = rng.below(4 << 20) + 1;
                    net.route(&mut env, src, dst, 0.0, b);
                    offer(&mut expect, src, dst, b);
                } else {
                    let b = rng.below(24 << 20) + 1;
                    let cfg = XferConfig {
                        chunk_bytes: 1 << rng.range(18, 22),
                        n_streams: rng.range(1, 9),
                        ..XferConfig::default()
                    };
                    let engine = XferEngine::new(cfg);
                    let mut faults = FaultInjector::none();
                    if rng.chance(0.5) {
                        faults.force_corrupt(0); // first chunk re-sent once
                    }
                    let req = TransferRequest {
                        id: i as u64,
                        owner: format!("o{i}"),
                        src_dc: src,
                        dst_dc: dst,
                        bytes: b,
                        priority: Priority::Bulk,
                        submitted_at: 0.0,
                    };
                    let rep = engine
                        .transfer(&mut env, &mut net, &req, &mut faults, 0.0)
                        .map_err(|e| e.to_string())?;
                    offer(&mut expect, src, dst, b + rep.retried_bytes);
                }
            }
            for (k, id) in ids.iter().enumerate() {
                let got = env.link(*id).total_bytes;
                crate::prop_assert!(
                    got == expect[k],
                    "link {k}: charged {got} != offered {}",
                    expect[k]
                );
            }
            Ok(())
        });
    }
}
