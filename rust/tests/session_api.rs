//! The Session API contract: typed errors for namespace visibility,
//! metadata-miss fallback charging, replicate signal plumbing, and the
//! tentpole acceptance — `run_batch` gives true processor-sharing
//! concurrency on the shared WAN instead of serialization.

use scispace::api::batch::run_batch_with_sds;
use scispace::api::{Op, OpResult, ScispaceError};
use scispace::db::Value;
use scispace::meu;
use scispace::namespace::Scope;
use scispace::sds::{Query, Sds, SdsConfig};
use scispace::workspace::{AccessMode, Testbed, TestbedConfig};

// ---------------------------------------------------------- visibility

#[test]
fn private_template_read_across_dcs_is_typed_not_visible() {
    let mut tb = Testbed::paper_default();
    let alice = tb.register("alice", 0);
    let bob = tb.register("bob", 1);
    tb.ns.define("alice-priv", "alice", "/home/alice", Scope::Local).unwrap();
    tb.session(alice).write("/home/alice/secret.dat").data(b"ssst").submit().unwrap();
    match tb.session(bob).read("/home/alice/secret.dat").len(4).submit() {
        Err(ScispaceError::NotVisible { path, viewer }) => {
            assert_eq!(path, "/home/alice/secret.dat");
            assert_eq!(viewer, "bob");
        }
        other => panic!("expected NotVisible, got {other:?}"),
    }
    // the replication data plane enforces the same scope, same type
    match tb.session(bob).replicate("/home/alice/secret.dat").to(1).submit() {
        Err(ScispaceError::NotVisible { viewer, .. }) => assert_eq!(viewer, "bob"),
        other => panic!("expected NotVisible, got {other:?}"),
    }
    // the owner still reads it fine, across the workspace
    assert!(tb.session(alice).read("/home/alice/secret.dat").submit().is_ok());
}

#[test]
fn overlapping_prefix_scopes_resolve_longest_match() {
    let mut tb = Testbed::paper_default();
    let alice = tb.register("alice", 0);
    let bob = tb.register("bob", 1);
    // a Local namespace nested inside a Global one, plus a sibling whose
    // name shares the prefix without a component boundary
    tb.ns.define("outer", "alice", "/collab/x", Scope::Global).unwrap();
    tb.ns.define("inner", "alice", "/collab/x/priv", Scope::Local).unwrap();
    let mut sess = tb.session(alice);
    sess.write("/collab/x/pub.dat").data(b"open").submit().unwrap();
    sess.write("/collab/x/priv/sec.dat").data(b"mine").submit().unwrap();
    sess.write("/collab/xz/f.dat").data(b"side").submit().unwrap();

    // outer Global: visible
    assert!(tb.session(bob).read("/collab/x/pub.dat").submit().is_ok());
    // inner Local wins the longest-prefix match: typed denial
    match tb.session(bob).read("/collab/x/priv/sec.dat").submit() {
        Err(ScispaceError::NotVisible { path, viewer }) => {
            assert_eq!(path, "/collab/x/priv/sec.dat");
            assert_eq!(viewer, "bob");
        }
        other => panic!("expected NotVisible, got {other:?}"),
    }
    // "/collab/xz" does not fall into "/collab/x" (component boundary):
    // default namespace, global
    assert!(tb.session(bob).read("/collab/xz/f.dat").submit().is_ok());
    // a missing path is NoSuchFile, not a visibility denial
    match tb.session(bob).read("/collab/x/priv/none.dat").submit() {
        Err(ScispaceError::NoSuchFile { path }) => assert_eq!(path, "/collab/x/priv/none.dat"),
        other => panic!("expected NoSuchFile, got {other:?}"),
    }
}

#[test]
fn lw_remote_read_is_typed_not_local() {
    let mut tb = Testbed::paper_default();
    let alice = tb.register("alice", 0);
    let bob = tb.register("bob", 1);
    tb.session(alice).write("/collab/far.dat").data(b"data").submit().unwrap();
    let (data_dc, _) = tb.session(alice).locate("/collab/far.dat").submit().unwrap().located().unwrap();
    let outsider = if tb.collabs[bob].dc != data_dc { bob } else { alice };
    if tb.collabs[outsider].dc != data_dc {
        match tb.session(outsider).read("/collab/far.dat").mode(AccessMode::ScispaceLw).submit() {
            Err(ScispaceError::NotLocal { path, dc }) => {
                assert_eq!(path, "/collab/far.dat");
                assert_eq!(dc, data_dc);
            }
            other => panic!("expected NotLocal, got {other:?}"),
        }
    }
}

// ------------------------------------------------- locate fallback cost

#[test]
fn locate_fallback_charges_consults_and_counts_stats() {
    let mut tb = Testbed::paper_default();
    let a = tb.register("a", 0);
    // an unexported LW file has no workspace metadata record
    tb.session(a)
        .write("/lw/file.dat")
        .len(1024)
        .mode(AccessMode::ScispaceLw)
        .submit()
        .unwrap();
    assert_eq!(tb.stats.locate_fallbacks, 0);
    let before = tb.now(a);
    let (dc, size) = tb.session(a).locate("/lw/file.dat").submit().unwrap().located().unwrap();
    assert_eq!(dc, 0);
    assert_eq!(size, 1024);
    assert_eq!(tb.stats.locate_fallbacks, 1, "metadata miss must be counted");
    assert!(tb.stats.locate_fallback_consults >= 1);
    assert!(tb.now(a) > before, "the per-DC consults must charge simulated time");

    // once exported, the metadata plane serves the lookup: no fallback
    meu::export(&mut tb, a, "/lw", None).unwrap();
    let n = tb.stats.locate_fallbacks;
    let t = tb.now(a);
    tb.session(a).locate("/lw/file.dat").submit().unwrap();
    assert_eq!(tb.stats.locate_fallbacks, n, "metadata hit must not fall back");
    assert_eq!(tb.now(a).to_bits(), t.to_bits(), "metadata-served locate stays free");
}

// ------------------------------------------- replicate signal plumbing

#[test]
fn replicate_reports_stream_goodput_and_path_losses() {
    let mut tb = Testbed::paper_default();
    let a = tb.register("a", 0);
    tb.session(a).write("/collab/big.dat").len(16 << 20).submit().unwrap();
    let rep = tb
        .session(a)
        .replicate("/collab/big.dat")
        .to(1)
        .submit()
        .unwrap()
        .replicated()
        .unwrap();
    assert_eq!(rep.bytes, 16 << 20);
    assert_eq!(rep.stream_goodput.len(), rep.streams, "one goodput sample per stripe");
    assert!(rep.stream_goodput.iter().all(|&g| g > 0.0), "{:?}", rep.stream_goodput);
    // cross-DC path: source LAN, WAN, destination LAN
    assert_eq!(rep.path_losses.len(), 3);
    assert!(rep.path_losses.iter().any(|p| p.link == "net.wan"));
    // the default WAN is lossless: deltas present, zero-valued
    assert!(rep.path_losses.iter().all(|p| p.losses == 0 && p.retransmit_bytes == 0));
}

#[test]
fn batch_replicate_reports_the_same_signal_set() {
    let mut tb = Testbed::paper_default();
    let a = tb.register("a", 0);
    tb.session(a).write("/collab/rep.dat").len(16 << 20).submit().unwrap();
    let results =
        tb.run_batch(vec![(a, Op::Replicate { path: "/collab/rep.dat".into(), dst_dc: 1 })]);
    let rep = results[0].clone().replicated().unwrap();
    assert_eq!(rep.bytes, 16 << 20);
    assert!(!rep.stream_goodput.is_empty());
    assert!(rep.stream_goodput.iter().all(|&g| g > 0.0));
    assert_eq!(rep.path_losses.len(), 3);
    // the replica materialized for real
    assert!(tb.dcs[1].fs.get("/collab/rep.dat").is_some());
}

// --------------------------------------------------- batch concurrency

fn wan_bottleneck_config() -> TestbedConfig {
    let mut cfg = TestbedConfig::paper_default();
    // make the shared inter-DC link the bottleneck by an order of
    // magnitude, so op latency is dominated by WAN serialization
    cfg.net.wan_bw = 100e6;
    cfg
}

/// Build a two-DC bed where reader `r{d}` (homed in DC d) has a remote
/// 32 MiB granule `/collab/shared/g{d}.dat` living in the *other* DC.
fn concurrency_bed() -> (Testbed, usize, usize) {
    let mut tb = Testbed::build(wan_bottleneck_config());
    let r0 = tb.register("r0", 0);
    let r1 = tb.register("r1", 1);
    let w0 = tb.register("w0", 0);
    let w1 = tb.register("w1", 1);
    // writer in DC1 publishes the granule reader0 will pull, and vice versa
    tb.session(w1).write("/collab/shared/g0.dat").len(32 << 20).submit().unwrap();
    tb.session(w0).write("/collab/shared/g1.dat").len(32 << 20).submit().unwrap();
    tb.quiesce();
    (tb, r0, r1)
}

fn read_op(d: usize) -> Op {
    Op::Read {
        path: format!("/collab/shared/g{d}.dat"),
        offset: 0,
        len: Some(32 << 20),
        mode: AccessMode::Scispace,
    }
}

#[test]
fn run_batch_overlaps_collaborators_on_the_shared_wan() {
    // Tentpole acceptance: two equal-size reads from collaborators in
    // different DCs over the shared WAN each finish in ~2x the solo
    // time (processor sharing), not serialized back-to-back (~>=2x for
    // one of them and ~1x for the other would also fail the band).
    let solo = {
        let (mut tb, r0, _) = concurrency_bed();
        let start = tb.now(r0);
        let results = tb.run_batch(vec![(r0, read_op(0))]);
        assert!(results[0].is_ok(), "{:?}", results[0].err());
        results[0].finished_at() - start
    };
    let (mut tb, r0, r1) = concurrency_bed();
    let start = tb.now(r0);
    assert_eq!(start, tb.now(r1), "quiesce aligns the clocks");
    let results = tb.run_batch(vec![(r0, read_op(0)), (r1, read_op(1))]);
    assert!(results.iter().all(|r| r.is_ok()), "{results:?}");
    let l0 = results[0].finished_at() - start;
    let l1 = results[1].finished_at() - start;
    let skew = (l0 - l1).abs() / l0.max(l1);
    assert!(skew < 0.05, "equal readers must finish together: {l0} vs {l1}");
    for l in [l0, l1] {
        let ratio = l / solo;
        assert!(
            (1.6..2.15).contains(&ratio),
            "shared WAN must halve each reader's bandwidth (PS), not serialize: \
             ratio={ratio} solo={solo} shared={l}"
        );
    }
    // both reads genuinely rode the WAN concurrently
    assert_eq!(tb.net.wan_peak(), 2);
}

#[test]
fn batch_bulk_write_then_remote_read_round_trips_bytes() {
    let mut tb = Testbed::paper_default();
    let a = tb.register("a", 0);
    let b = tb.register("b", 1);
    let payload: Vec<u8> = (0..(9u32 << 20)).map(|i| (i % 251) as u8).collect();
    let results = tb.run_batch(vec![(
        a,
        Op::Write {
            path: "/batch/pay.dat".into(),
            offset: 0,
            len: payload.len() as u64,
            data: Some(payload.clone()),
            mode: AccessMode::Scispace,
        },
    )]);
    assert!(results[0].is_ok(), "{:?}", results[0].err());
    let results = tb.run_batch(vec![(
        b,
        Op::Read {
            path: "/batch/pay.dat".into(),
            offset: 0,
            len: Some(payload.len() as u64),
            mode: AccessMode::Scispace,
        },
    )]);
    let bytes = results[0].clone().data().unwrap();
    assert_eq!(bytes, payload, "the batch data plane must move real bytes");
}

// ------------------------------------------ batch-of-one equivalence

/// Sum of (bytes, ops) served on every DTN metadata/digest CPU — the
/// accounting surface where chunk digests land.
fn dtn_cpu_totals(tb: &Testbed) -> (u64, u64) {
    (0..tb.dtns.len()).fold((0, 0), |(b, o), i| {
        let r = tb.env.server(tb.dtns[i].meta_cpu);
        (b + r.total_bytes, o + r.total_ops)
    })
}

/// Assert two beds are in bit-identical observable state: every
/// collaborator clock, the op-level stats, the DTN CPU digest/metadata
/// accounting, and the WAN byte counters.
fn assert_beds_identical(a: &Testbed, b: &Testbed, step: &str) {
    for c in 0..a.collabs.len() {
        assert_eq!(
            a.now(c).to_bits(),
            b.now(c).to_bits(),
            "{step}: collaborator {c} clock drifted: {} vs {}",
            a.now(c),
            b.now(c)
        );
    }
    assert_eq!(a.stats.locate_fallbacks, b.stats.locate_fallbacks, "{step}: fallbacks");
    assert_eq!(
        a.stats.locate_fallback_consults, b.stats.locate_fallback_consults,
        "{step}: fallback consults"
    );
    assert_eq!(dtn_cpu_totals(a), dtn_cpu_totals(b), "{step}: DTN CPU digest/meta accounting");
    assert_eq!(
        a.env.link(a.net.wan.res).total_bytes,
        b.env.link(b.net.wan.res).total_bytes,
        "{step}: WAN bytes"
    );
}

fn norm(r: Result<OpResult, ScispaceError>) -> OpResult {
    r.unwrap_or_else(OpResult::Failed)
}

/// Same variant, same bits, same payload/report.
fn assert_results_identical(a: &OpResult, b: &OpResult, step: &str) {
    assert_eq!(
        a.finished_at().to_bits(),
        b.finished_at().to_bits(),
        "{step}: finished_at {} vs {}",
        a.finished_at(),
        b.finished_at()
    );
    match (a, b) {
        (OpResult::Data { bytes: x, .. }, OpResult::Data { bytes: y, .. }) => {
            assert_eq!(x, y, "{step}: payload")
        }
        (
            OpResult::Written { path: px, bytes: x, .. },
            OpResult::Written { path: py, bytes: y, .. },
        ) => assert_eq!((px, x), (py, y), "{step}: write result"),
        (OpResult::Listing { entries: x, .. }, OpResult::Listing { entries: y, .. }) => {
            let xs: Vec<&str> = x.iter().map(|m| m.path.as_str()).collect();
            let ys: Vec<&str> = y.iter().map(|m| m.path.as_str()).collect();
            assert_eq!(xs, ys, "{step}: listing")
        }
        (
            OpResult::Located { dc: dx, size: sx, .. },
            OpResult::Located { dc: dy, size: sy, .. },
        ) => assert_eq!((dx, sx), (dy, sy), "{step}: locate result"),
        (OpResult::Replicated(x), OpResult::Replicated(y)) => {
            assert_eq!(x.bytes, y.bytes, "{step}: bytes");
            assert_eq!(x.chunks, y.chunks, "{step}: chunk accounting must match single-op");
            assert_eq!(x.streams, y.streams, "{step}: streams");
            assert_eq!(
                (x.retried_chunks, x.retried_bytes),
                (y.retried_chunks, y.retried_bytes),
                "{step}: retries"
            );
            assert_eq!(
                (x.cc_losses, x.cc_retransmit_bytes),
                (y.cc_losses, y.cc_retransmit_bytes),
                "{step}: congestion accounting"
            );
            assert_eq!(x.started_at.to_bits(), y.started_at.to_bits(), "{step}: started_at");
            let gx: Vec<u64> = x.stream_goodput.iter().map(|g| g.to_bits()).collect();
            let gy: Vec<u64> = y.stream_goodput.iter().map(|g| g.to_bits()).collect();
            assert_eq!(gx, gy, "{step}: per-stream goodput");
            assert_eq!(x.path_losses, y.path_losses, "{step}: path losses");
        }
        (OpResult::Hits { files: x, .. }, OpResult::Hits { files: y, .. }) => {
            assert_eq!(x, y, "{step}: hits")
        }
        (OpResult::Tagged { .. }, OpResult::Tagged { .. }) => {}
        (OpResult::Failed(x), OpResult::Failed(y)) => assert_eq!(x, y, "{step}: error"),
        (x, y) => panic!("{step}: variant mismatch: {x:?} vs {y:?}"),
    }
}

/// The two beds kept in lockstep: `single` executes every op as a
/// plain Session call, `batch` as a one-element `run_batch`.
struct Lockstep {
    single: Testbed,
    batch: Testbed,
    sds_single: Sds,
    sds_batch: Sds,
}

/// Run `op` both ways; the beds must remain bit-identical.
fn check_one(beds: &mut Lockstep, c: usize, op: Op, step: &str) {
    let ra = norm(beds.single.session(c).submit_with_sds(&mut beds.sds_single, op.clone()));
    let rb = run_batch_with_sds(&mut beds.batch, &mut beds.sds_batch, vec![(c, op)])
        .pop()
        .expect("one result per op");
    assert_results_identical(&ra, &rb, step);
    assert_beds_identical(&beds.single, &beds.batch, step);
}

/// ISSUE 5 acceptance: for **every** `Op` variant (and every
/// interesting lowering of Read/Write — small, bulk, native, whole
/// file, typed failure), a one-element `run_batch` is bit-identical to
/// the corresponding single-op Session call: timing, stats, DTN-CPU
/// digest accounting, WAN accounting and the `OpResult` itself. This
/// extends the PR 4 pin from a few ops to the full enum.
#[test]
fn batch_of_one_is_bit_identical_to_single_op_for_every_variant() {
    let mut single = Testbed::paper_default();
    let mut batch = Testbed::paper_default();
    let c0 = single.register("c0", 0);
    let c1 = single.register("c1", 1);
    assert_eq!(c0, batch.register("c0", 0));
    assert_eq!(c1, batch.register("c1", 1));
    let n_dtns = single.dtns.len();
    let mut beds = Lockstep {
        single,
        batch,
        sds_single: Sds::new(n_dtns, SdsConfig::default()),
        sds_batch: Sds::new(n_dtns, SdsConfig::default()),
    };
    check_one(
        &mut beds,
        c0,
        Op::Write {
            path: "/eq/x.dat".into(),
            offset: 0,
            len: 5,
            data: Some(b"hello".to_vec()),
            mode: AccessMode::Scispace,
        },
        "small create write",
    );
    check_one(
        &mut beds,
        c0,
        Op::Write {
            path: "/eq/big.dat".into(),
            offset: 0,
            len: 16 << 20,
            data: None,
            mode: AccessMode::Scispace,
        },
        "bulk synthetic write (chunked engine path)",
    );
    check_one(
        &mut beds,
        c0,
        Op::Write {
            path: "/eq-lw/l.dat".into(),
            offset: 0,
            len: 1024,
            data: None,
            mode: AccessMode::ScispaceLw,
        },
        "native LW write",
    );
    check_one(
        &mut beds,
        c1,
        Op::Read { path: "/eq/x.dat".into(), offset: 0, len: Some(5), mode: AccessMode::Scispace },
        "small remote read (rpc path)",
    );
    check_one(
        &mut beds,
        c1,
        Op::Read {
            path: "/eq/big.dat".into(),
            offset: 0,
            len: Some(16 << 20),
            mode: AccessMode::Scispace,
        },
        "bulk remote read (chunked engine path)",
    );
    check_one(
        &mut beds,
        c1,
        Op::Read { path: "/eq/x.dat".into(), offset: 0, len: None, mode: AccessMode::Scispace },
        "whole-file read (resolved length)",
    );
    // A namespace entry whose backing object vanished from the store
    // must surface as the typed `NoSuchFile` — never a silent
    // zero-byte read — and both lowerings must charge identically.
    check_one(
        &mut beds,
        c0,
        Op::Write {
            path: "/eq/vanish.dat".into(),
            offset: 0,
            len: 9,
            data: Some(b"ephemeral".to_vec()),
            mode: AccessMode::Scispace,
        },
        "create soon-to-vanish file",
    );
    for tb in [&mut beds.single, &mut beds.batch] {
        let obj = tb.dcs[0].fs.get("/eq/vanish.dat").and_then(|e| e.obj).expect("backing object");
        assert!(tb.dcs[0].store.remove(obj), "object present before removal");
    }
    check_one(
        &mut beds,
        c1,
        Op::Read {
            path: "/eq/vanish.dat".into(),
            offset: 0,
            len: None,
            mode: AccessMode::Scispace,
        },
        "vanished-object whole-file read (typed NoSuchFile)",
    );
    check_one(
        &mut beds,
        c1,
        Op::Read {
            path: "/eq/missing.dat".into(),
            offset: 0,
            len: Some(4),
            mode: AccessMode::Scispace,
        },
        "missing read (typed failure, charged fallback)",
    );
    check_one(
        &mut beds,
        c1,
        Op::Ls { prefix: "/eq".into() },
        "ls fan-out",
    );
    check_one(
        &mut beds,
        c0,
        Op::Locate { path: "/eq/x.dat".into() },
        "locate",
    );
    check_one(
        &mut beds,
        c0,
        Op::Replicate { path: "/eq/big.dat".into(), dst_dc: 1 },
        "bulk replicate (chunked engine path, both digest sinks)",
    );
    check_one(
        &mut beds,
        c0,
        Op::Replicate { path: "/eq/big.dat".into(), dst_dc: 0 },
        "replicate failure (already replicated)",
    );
    check_one(
        &mut beds,
        c0,
        Op::Tag { path: "/eq/x.dat".into(), attr: "kind".into(), value: Value::Int(7) },
        "tag",
    );
    check_one(
        &mut beds,
        c1,
        Op::Query { query: Query::parse("kind = 7").unwrap() },
        "query",
    );
}

// ------------------------------------------------- integrity parity

#[test]
fn batch_bulk_write_charges_chunk_digests_identically_on_the_dtn_cpu() {
    // ISSUE 5 satellite: a batch bulk write must charge exactly the
    // same chunk-digest work on the DTN meta_cpu as the equivalent
    // single-op write — the old flow-lowered batch skipped it entirely.
    let len = 32u64 << 20;
    let mut single = Testbed::paper_default();
    let mut batch = Testbed::paper_default();
    let a = single.register("a", 0);
    assert_eq!(a, batch.register("a", 0));
    let before = dtn_cpu_totals(&single);
    assert_eq!(before, dtn_cpu_totals(&batch));
    single.session(a).write("/par/big.dat").len(len).submit().unwrap();
    let r = batch.run_batch(vec![(
        a,
        Op::Write {
            path: "/par/big.dat".into(),
            offset: 0,
            len,
            data: None,
            mode: AccessMode::Scispace,
        },
    )]);
    assert!(r[0].is_ok(), "{:?}", r[0].err());
    let after_s = dtn_cpu_totals(&single);
    let after_b = dtn_cpu_totals(&batch);
    assert_eq!(after_s, after_b, "batch and single-op must charge identical DTN CPU work");
    assert_eq!(after_s.0 - before.0, len, "every chunk digested exactly once, by bytes");
    let chunks = len.div_ceil(single.cfg.xfer.chunk_bytes);
    assert!(
        after_s.1 - before.1 >= chunks,
        "at least one digest service op per chunk: {} vs {chunks}",
        after_s.1 - before.1
    );
}

// ---------------------------------------------------- no cross-stall

/// A 3-DC bed: alice (dc0) owns a 1 GiB granule in dc0; bob (dc2) has
/// a local 1 MiB file in dc2. Alice's bulk replicate (dc0 -> dc1) and
/// bob's ops touch disjoint payload links.
fn asymmetric_bed() -> (Testbed, usize, usize) {
    let mut cfg = TestbedConfig::paper_default();
    cfg.n_dcs = 3;
    let mut tb = Testbed::build(cfg);
    let alice = tb.register("alice", 0);
    let bob = tb.register("bob", 2);
    tb.session(alice).write("/big/src.dat").len(1 << 30).submit().unwrap();
    tb.session(bob).write("/b2/local.dat").len(1 << 20).submit().unwrap();
    tb.quiesce();
    (tb, alice, bob)
}

fn bob_ops(bob: usize) -> Vec<(usize, Op)> {
    vec![
        (bob, Op::Ls { prefix: "/b2".into() }),
        (bob, Op::Read {
            path: "/b2/local.dat".into(),
            offset: 0,
            len: Some(1 << 20),
            mode: AccessMode::Scispace,
        }),
    ]
}

#[test]
fn interactive_op_is_not_stalled_by_unrelated_concurrent_bulk() {
    // ISSUE 5 satellite: an interactive read submitted concurrently
    // with an unrelated multi-GB bulk replicate on disjoint links must
    // complete within 1% of its solo latency. The wave model failed
    // this shape (an op admitted after round k joined shared state no
    // earlier than round k's horizon, and the first chunk's digest
    // serve could commit a far-future FIFO horizon at admission);
    // event-driven per-collaborator admission pins the fix.
    let solo = {
        let (mut tb, _alice, bob) = asymmetric_bed();
        let start = tb.now(bob);
        let results = tb.run_batch(bob_ops(bob));
        assert!(results.iter().all(|r| r.is_ok()), "{results:?}");
        results[1].finished_at() - start
    };
    let (mut tb, alice, bob) = asymmetric_bed();
    let start = tb.now(bob);
    assert_eq!(start, tb.now(alice), "quiesce aligns the clocks");
    let mut ops = vec![(alice, Op::Replicate { path: "/big/src.dat".into(), dst_dc: 1 })];
    ops.extend(bob_ops(bob));
    let results = tb.run_batch(ops);
    assert!(results.iter().all(|r| r.is_ok()), "{results:?}");
    let bulk = results[0].finished_at() - start;
    let read = results[2].finished_at() - start;
    let skew = (read - solo).abs() / solo;
    assert!(
        skew < 0.01,
        "unrelated concurrent bulk must not stall the interactive read: \
         solo={solo} concurrent={read} skew={skew}"
    );
    assert!(
        bulk > 5.0 * read,
        "the bulk replicate must genuinely outlast the read it overlapped: \
         bulk={bulk} read={read}"
    );
}

#[test]
fn batch_preserves_per_collaborator_program_order() {
    let mut tb = Testbed::paper_default();
    let a = tb.register("a", 0);
    let ops = vec![
        (a, Op::Write { path: "/ord/x.dat".into(), offset: 0, len: 4, data: Some(b"one!".to_vec()), mode: AccessMode::Scispace }),
        (a, Op::Read { path: "/ord/x.dat".into(), offset: 0, len: Some(4), mode: AccessMode::Scispace }),
        (a, Op::Ls { prefix: "/ord".into() }),
    ];
    let results = tb.run_batch(ops);
    assert!(results.iter().all(|r| r.is_ok()), "{results:?}");
    // completions are monotone for one collaborator (serial program order)
    let t: Vec<f64> = results.iter().map(|r| r.finished_at()).collect();
    assert!(t[0] <= t[1] && t[1] <= t[2], "{t:?}");
    match &results[1] {
        OpResult::Data { bytes, .. } => assert_eq!(bytes, b"one!"),
        other => panic!("expected Data, got {other:?}"),
    }
}
