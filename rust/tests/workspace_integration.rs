//! Integration: full collaboration scenarios across workspace + metadata
//! + MEU + SDS + namespaces on the simulated two-DC testbed, driven
//! through the typed Session API.

use scispace::api::ScispaceError;
use scispace::db::Value;
use scispace::meu;
use scispace::namespace::Scope;
use scispace::sds::{self, ExtractionMode, Sds, SdsConfig};
use scispace::workload::{load_corpus, modis_corpus, ModisConfig};
use scispace::workspace::{AccessMode, Testbed};

fn ls_paths(tb: &mut Testbed, c: usize, prefix: &str) -> Vec<String> {
    tb.session(c)
        .ls(prefix)
        .submit()
        .unwrap()
        .entries()
        .unwrap()
        .into_iter()
        .map(|m| m.path)
        .collect()
}

#[test]
fn two_site_share_and_analyze() {
    let mut tb = Testbed::paper_default();
    let a = tb.register("alice", 0);
    let b = tb.register("bob", 1);
    let corpus = modis_corpus(&ModisConfig { n_files: 20, elems_per_file: 512, seed: 9 });
    load_corpus(&mut tb, a, &corpus, AccessMode::Scispace);
    // bob sees all granules and can parse one
    let mut sess = tb.session(b);
    let ls = sess.ls("/modis").submit().unwrap().entries().unwrap();
    assert_eq!(ls.len(), 20);
    let raw = sess.read(&ls[3].path).len(ls[3].size).submit().unwrap().data().unwrap();
    let f: scispace::shdf::ShdfFile = scispace::msg::Wire::from_bytes(&raw).unwrap();
    assert!(f.get_attr("Instrument").is_some());
}

#[test]
fn lw_plus_meu_equals_workspace_visibility() {
    // Writing natively + MEU must converge to the same workspace view as
    // writing through scifs directly.
    let corpus = modis_corpus(&ModisConfig { n_files: 12, elems_per_file: 256, seed: 10 });

    let mut tb1 = Testbed::paper_default();
    let c1 = tb1.register("x", 0);
    let viewer1 = tb1.register("v", 1);
    load_corpus(&mut tb1, c1, &corpus, AccessMode::Scispace);
    let direct = ls_paths(&mut tb1, viewer1, "/modis");

    let mut tb2 = Testbed::paper_default();
    let c2 = tb2.register("x", 0);
    let viewer2 = tb2.register("v", 1);
    load_corpus(&mut tb2, c2, &corpus, AccessMode::ScispaceLw);
    meu::export(&mut tb2, c2, "/", None).unwrap();
    let exported = ls_paths(&mut tb2, viewer2, "/modis");

    assert_eq!(direct, exported);
}

#[test]
fn multi_collaboration_scopes_isolate() {
    let mut tb = Testbed::paper_default();
    let alice = tb.register("alice", 0);
    let bob = tb.register("bob", 1);
    let carol = tb.register("carol", 0);
    tb.ns.define("ab-collab", "alice", "/collab/ab", Scope::Global).unwrap();
    tb.ns.define("alice-private", "alice", "/priv/alice", Scope::Local).unwrap();
    let mut sess = tb.session(alice);
    sess.write("/collab/ab/shared.dat").data(b"ab!!").submit().unwrap();
    sess.write("/priv/alice/own.dat").data(b"mine").submit().unwrap();
    // bob: sees the global collab, not the private namespace — and the
    // denial is typed, not a string
    assert_eq!(ls_paths(&mut tb, bob, "/").len(), 1);
    match tb.session(bob).read("/priv/alice/own.dat").len(4).submit() {
        Err(ScispaceError::NotVisible { path, viewer }) => {
            assert_eq!(path, "/priv/alice/own.dat");
            assert_eq!(viewer, "bob");
        }
        other => panic!("expected NotVisible, got {other:?}"),
    }
    // carol: same DC as alice but still scope-filtered
    assert_eq!(ls_paths(&mut tb, carol, "/priv").len(), 0);
    // alice sees both
    assert_eq!(ls_paths(&mut tb, alice, "/").len(), 2);
}

#[test]
fn sds_modes_converge_to_same_index() {
    let corpus = modis_corpus(&ModisConfig { n_files: 15, elems_per_file: 256, seed: 11 });
    let count_hits = |mode: ExtractionMode| -> usize {
        let mut tb = Testbed::paper_default();
        let c = tb.register("w", 0);
        let mut sds = Sds::new(tb.dtns.len(), SdsConfig::default());
        for (p, f) in &corpus {
            tb.session(c).write_indexed(&mut sds, p, f).extraction(mode).submit().unwrap();
        }
        match mode {
            ExtractionMode::InlineAsync => {
                sds::process_queue(&mut tb, &mut sds, None).unwrap();
            }
            ExtractionMode::LwOffline => {
                sds::offline_index(&mut tb, &mut sds, c, "/modis", None).unwrap();
            }
            ExtractionMode::InlineSync => {}
        }
        tb.quiesce();
        let files = tb
            .session(c)
            .query(&mut sds, "Instrument like %")
            .submit()
            .unwrap()
            .files()
            .unwrap();
        files.len()
    };
    let sync = count_hits(ExtractionMode::InlineSync);
    let asynch = count_hits(ExtractionMode::InlineAsync);
    let offline = count_hits(ExtractionMode::LwOffline);
    assert_eq!(sync, corpus.len());
    assert_eq!(sync, asynch, "async mode must converge to the sync index");
    assert_eq!(sync, offline, "offline mode must converge to the sync index");
}

#[test]
fn unsynced_lw_files_invisible_until_export_then_queryable() {
    let mut tb = Testbed::paper_default();
    let w = tb.register("w", 1);
    let r = tb.register("r", 0);
    let mut sds = Sds::new(tb.dtns.len(), SdsConfig::default());
    let corpus = modis_corpus(&ModisConfig { n_files: 6, elems_per_file: 128, seed: 12 });
    load_corpus(&mut tb, w, &corpus, AccessMode::ScispaceLw);
    assert!(ls_paths(&mut tb, r, "/modis").is_empty());
    meu::export(&mut tb, w, "/", None).unwrap();
    sds::offline_index(&mut tb, &mut sds, w, "/modis", None).unwrap();
    tb.quiesce();
    assert_eq!(ls_paths(&mut tb, r, "/modis").len(), 6);
    let files =
        tb.session(r).query(&mut sds, "GranuleId < 3").submit().unwrap().files().unwrap();
    assert_eq!(files.len(), 3);
}

#[test]
fn remote_delete_extension_works() {
    // DESIGN.md §8: the paper defers remote removal to the metadata
    // service; verify the extension path.
    let mut tb = Testbed::paper_default();
    let a = tb.register("a", 0);
    let b = tb.register("b", 1);
    tb.session(a).write("/d/gone.dat").data(b"temp").submit().unwrap();
    assert_eq!(ls_paths(&mut tb, b, "/d").len(), 1);
    use scispace::metadata::{MetaReq, MetaResp};
    assert_eq!(tb.meta.route(&MetaReq::Delete("/d/gone.dat".into())), MetaResp::Ok(1));
    assert!(ls_paths(&mut tb, b, "/d").is_empty());
}

#[test]
fn interleaved_collaborators_make_progress() {
    // 8 collaborators on both DCs interleave writes + reads + ls without
    // interfering with each other's data.
    let mut tb = Testbed::paper_default();
    for i in 0..8 {
        tb.register(&format!("c{i}"), i % 2);
    }
    for round in 0..5u64 {
        for c in 0..8usize {
            let path = format!("/work/c{c}/r{round}.dat");
            let payload = format!("payload-{c}-{round}");
            tb.session(c).write(&path).data(payload.as_bytes()).submit().unwrap();
        }
    }
    for c in 0..8usize {
        for round in 0..5u64 {
            let path = format!("/work/c{c}/r{round}.dat");
            let want = format!("payload-{c}-{round}");
            let got = tb.session(c).read(&path).submit().unwrap().data().unwrap();
            assert_eq!(got, want.as_bytes());
        }
    }
    assert_eq!(ls_paths(&mut tb, 0, "/work").len(), 40);
    // times advanced monotonically for everyone
    assert!((0..8).all(|c| tb.now(c) > 0.0));
}

#[test]
fn batch_mixes_workspace_and_sds_ops() {
    use scispace::api::{batch, Op, OpResult};
    let mut tb = Testbed::paper_default();
    let a = tb.register("alice", 0);
    let b = tb.register("bob", 1);
    let mut sds = Sds::new(tb.dtns.len(), SdsConfig::default());
    tb.session(a).write("/mix/x.dat").data(b"xx").submit().unwrap();
    let ops = vec![
        (a, Op::Tag {
            path: "/mix/x.dat".into(),
            attr: "campaign".into(),
            value: Value::Text("alpha".into()),
        }),
        (b, Op::Ls { prefix: "/mix".into() }),
        (a, Op::Query { query: scispace::sds::Query::parse("campaign = alpha").unwrap() }),
        (b, Op::Read { path: "/missing.dat".into(), offset: 0, len: Some(4), mode: AccessMode::Scispace }),
    ];
    let results = batch::run_batch_with_sds(&mut tb, &mut sds, ops);
    assert_eq!(results.len(), 4);
    assert!(results[0].is_ok(), "tag: {results:?}");
    assert_eq!(results[1].clone().entries().unwrap().len(), 1);
    assert_eq!(results[2].clone().files().unwrap(), vec!["/mix/x.dat".to_string()]);
    match &results[3] {
        OpResult::Failed(ScispaceError::NoSuchFile { path }) => assert_eq!(path, "/missing.dat"),
        other => panic!("expected NoSuchFile, got {other:?}"),
    }
}
