//! Striped parallel streams: N logical connections over one network path.
//!
//! Each stream carries chunks stop-and-wait (send, checksum, ack) as a
//! flow over the engine's processor-sharing links ([`crate::engine`]) —
//! so bytes still serialize at link bandwidth, but the per-chunk latency
//! and checksum overhead that throttles a single stream is paid in
//! parallel. That is exactly why GridFTP-style movers stripe: transfer
//! time falls with stream count until the link's byte-serialization floor
//! is reached, then plateaus.

use crate::engine::{Engine, LinkId};
use crate::simnet::Link;

use super::XferConfig;

/// The per-transfer stream group.
#[derive(Debug, Clone)]
pub struct StreamSet {
    clocks: Vec<f64>,
    live: Vec<bool>,
    sent: Vec<u64>,
    /// Latest chunk-completion time observed (the transfer makespan).
    last_done: f64,
}

impl StreamSet {
    /// Open `n` streams at virtual time `start`; connection setup is
    /// paid once, in parallel, by every stream.
    pub fn new(n: usize, start: f64, setup_s: f64) -> Self {
        assert!(n > 0, "need at least one stream");
        StreamSet {
            clocks: vec![start + setup_s; n],
            live: vec![true; n],
            sent: vec![0; n],
            last_done: start,
        }
    }

    /// Number of streams opened (live or dead).
    pub fn width(&self) -> usize {
        self.clocks.len()
    }

    /// Live streams remaining.
    pub fn live_count(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }

    /// Chunks delivered by stream `s` (including retries it carried).
    pub fn sent(&self, s: usize) -> u64 {
        self.sent[s]
    }

    /// The live stream with the earliest local clock (deterministic:
    /// lowest index wins ties), or `None` when every stream has died.
    pub fn best_live(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for s in 0..self.clocks.len() {
            if !self.live[s] {
                continue;
            }
            match best {
                Some(b) if self.clocks[b] <= self.clocks[s] => {}
                _ => best = Some(s),
            }
        }
        best
    }

    /// Carry one chunk of `len` bytes over `path` on stream `s`: one
    /// flow traverses every hop (sharing each link with whatever other
    /// streams and transfers ride it), checksum at both endpoints, then
    /// wait for the ack to travel back. Returns the chunk completion
    /// time.
    pub fn send_chunk(
        &mut self,
        env: &mut Engine,
        path: &[Link],
        s: usize,
        len: u64,
        cfg: &XferConfig,
    ) -> f64 {
        debug_assert!(self.live[s], "sending on a dead stream");
        let ids: Vec<LinkId> = path.iter().map(|l| l.res).collect();
        let flow = env.start_flow(&ids, len, self.clocks[s], 1.0);
        let mut t = env.completion(flow);
        // sender + receiver digest the chunk
        if cfg.checksum_bw.is_finite() && cfg.checksum_bw > 0.0 {
            t += 2.0 * len as f64 / cfg.checksum_bw;
        }
        // ack rides back latency-only (it is a few bytes)
        t += path.iter().map(|l| l.latency_s).sum::<f64>() + cfg.ack_op_s;
        self.clocks[s] = t;
        self.sent[s] += 1;
        self.last_done = self.last_done.max(t);
        t
    }

    /// Kill stream `s` (fail injection).
    pub fn kill(&mut self, s: usize) {
        self.live[s] = false;
    }

    /// Re-open stream `s` at time `at` (reconnect after total stream
    /// loss) paying the connection setup again.
    pub fn revive(&mut self, s: usize, at: f64, setup_s: f64) {
        self.live[s] = true;
        self.clocks[s] = at + setup_s;
    }

    /// Latest clock across all streams (used for reconnect timing).
    pub fn horizon(&self) -> f64 {
        self.clocks.iter().copied().fold(self.last_done, f64::max)
    }

    /// Latest chunk completion observed so far.
    pub fn makespan(&self) -> f64 {
        self.last_done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::{NetConfig, Network};

    fn setup() -> (Engine, Network, XferConfig) {
        let mut env = Engine::new();
        let net = Network::build(&mut env, &NetConfig::paper_default(), 2);
        (env, net, XferConfig::default())
    }

    #[test]
    fn single_stream_serializes_chunks() {
        let (mut env, net, cfg) = setup();
        let path = net.path(0, 1);
        let mut ss = StreamSet::new(1, 0.0, cfg.stream_setup_s);
        let t1 = ss.send_chunk(&mut env, &path, 0, 1 << 20, &cfg);
        let t2 = ss.send_chunk(&mut env, &path, 0, 1 << 20, &cfg);
        assert!(t2 > t1);
        assert_eq!(ss.sent(0), 2);
        assert!((ss.makespan() - t2).abs() < 1e-12);
    }

    #[test]
    fn streams_share_link_bytes() {
        let (mut env, net, cfg) = setup();
        let path = net.path(0, 1);
        let mut ss = StreamSet::new(4, 0.0, cfg.stream_setup_s);
        for _ in 0..8 {
            let s = ss.best_live().unwrap();
            ss.send_chunk(&mut env, &path, s, 1 << 20, &cfg);
        }
        // every link carried all bytes exactly once per chunk
        assert_eq!(env.link(net.wan.res).total_bytes, 8 << 20);
        assert_eq!(env.link(net.lans[0].res).total_bytes, 8 << 20);
        assert_eq!(env.link(net.lans[1].res).total_bytes, 8 << 20);
    }

    #[test]
    fn best_live_skips_dead_streams() {
        let (_env, _net, cfg) = setup();
        let mut ss = StreamSet::new(3, 0.0, cfg.stream_setup_s);
        ss.kill(0);
        assert_eq!(ss.best_live(), Some(1));
        ss.kill(1);
        ss.kill(2);
        assert_eq!(ss.best_live(), None);
        assert_eq!(ss.live_count(), 0);
        ss.revive(2, 1.0, cfg.stream_setup_s);
        assert_eq!(ss.best_live(), Some(2));
    }
}
