//! `xfer` — the WAN bulk data-transfer engine (the data mover SCISPACE
//! assumes but the paper never details).
//!
//! The paper's premise is that ESnet-class terabit WANs make bulk data
//! motion between geo-distributed centers cheap enough to collaborate
//! through one namespace. This layer makes that motion a first-class,
//! measurable component instead of a single monolithic `route()` call:
//!
//! * [`stream`]    — a transfer is split into chunks striped across N
//!   concurrent streams that share link bandwidth (processor-sharing
//!   [`crate::engine`] links), so per-chunk latency pipelines while
//!   bytes still serialize at the link floor (GridFTP-style striping).
//! * [`sched`]     — a priority + per-collaboration fair-share queue
//!   dispatches chunks across concurrent transfers, plus an
//!   event-driven flow scheduler ([`run_flows`]) where each admitted
//!   transfer runs as long-lived weighted flows and an Interactive
//!   arrival can *preempt* admitted Bulk/Scavenger flows mid-transfer
//!   (the `fig_preempt` bench measures the tail-latency win).
//! * [`integrity`] — chunk checksums, deterministic fault injection
//!   (corrupt chunk, dying stream) and retry of *only* the affected
//!   chunks.
//! * [`tune`]      — the stream-count autotuner (see below).
//!
//! ## Stream autotuning
//!
//! A fixed stream count is wrong almost everywhere: on a lossy WAN the
//! goodput-vs-width curve rises, peaks, then collapses (the
//! over-striping cliff `bench::fig_xfer_streams_cc` measures). With
//! [`TuneConfig::adaptive`] in [`XferConfig::tune`], every [`Flight`]
//! carries an [`Autotuner`] that observes one **chunk round** at a time
//! (one chunk per open stream) and hill-climbs the width toward the
//! goodput peak:
//!
//! * **widen** while each step's marginal aggregate-goodput yield
//!   clears [`TuneConfig::widen_margin`];
//! * **shed** a quarter of the width the moment the transfer's *own*
//!   flow-local loss deltas ([`Engine::flow_link_losses`]) climb past
//!   [`TuneConfig::loss_shed_frac`] of the round's delivered bytes;
//! * **hold** at the best measured width otherwise, re-probing one step
//!   after a calm spell.
//!
//! The chunk-boundary rule: adaptation only ever happens between
//! chunks — a chunk in flight is never re-striped — so the blocking
//! path ([`XferEngine::transfer_with_sinks`]), the batch executor and
//! the queue dispatcher ([`run_queue`]) all adapt identically, and
//! [`TuneMode::Fixed`] stays bit-identical to the pre-autotuner engine
//! (pinned by `tests/xfer_tune.rs`). Learned widths persist per
//! `(src_dc, dst_dc)` path in a [`PathStateTable`], seeding the next
//! transfer on the path — including repair re-replication
//! (`metadata::replication`) — at the settled width. Decisions are
//! observable as [`TraceEvent::Tune`] events and a width-over-time
//! metrics series.
//!
//! The engine is consumed by [`crate::workspace`] (remote reads/writes
//! above a size threshold), [`crate::metadata::replication`] (data-plane
//! repair after a DTN outage), the `scispace xfer` CLI and the
//! `fig_xfer_streams` / `fig_preempt` / `fig_xfer_adaptive` benches.

pub mod integrity;
pub mod sched;
pub mod stream;
pub mod tune;

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::engine::{CcConfig, Engine};
use crate::obs::{SpanId, TraceEvent};
use crate::simnet::{Link, Network};

pub use integrity::{checksum, chunk_spans, Chunk, DigestSinks, FaultInjector};
pub use sched::{run_flows, run_queue, run_queue_tuned, FlowReport, TransferQueue};
pub use stream::{ChunkFlight, StreamSet};
pub use tune::{
    Autotuner, PathState, PathStateTable, RoundObs, TuneAction, TuneConfig, TuneMode, TuneOutcome,
};

/// Transfer priority class; the weight steers both queue admission and
/// per-chunk dispatch between concurrent transfers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Background scavenger traffic (weight 1).
    Scavenger,
    /// Bulk replication / dataset sync (weight 2).
    Bulk,
    /// Interactive collaborator reads (weight 8).
    Interactive,
}

impl Priority {
    /// Fair-share weight of the class.
    pub fn weight(self) -> f64 {
        match self {
            Priority::Scavenger => 1.0,
            Priority::Bulk => 2.0,
            Priority::Interactive => 8.0,
        }
    }

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Priority::Scavenger => "scavenger",
            Priority::Bulk => "bulk",
            Priority::Interactive => "interactive",
        }
    }
}

/// Congestion-control tuning for a transfer's streams.
///
/// When enabled, every stream runs as a *windowed* flow
/// ([`Engine::start_windowed_flow`]): its rate is capped at
/// `window / rtt` on congestion-managed links and it suffers
/// multiplicative decrease + go-back retransmission when a sustained
/// overload synthesizes loss there. Striping N streams multiplies the
/// aggregate window (and its growth) by N — and multiplies the loss
/// exposure the same way, which is where the over-striping collapse
/// comes from. Disabled (the default), streams are plain
/// processor-sharing flows and every pre-congestion behaviour is
/// byte-identical.
#[derive(Debug, Clone, Default)]
pub struct CongestionConfig {
    /// Run streams as AIMD windowed flows.
    pub enabled: bool,
    /// Per-stream window parameters.
    pub window: CcConfig,
}

impl CongestionConfig {
    /// Congestion control on, with the default AIMD window.
    pub fn on() -> Self {
        CongestionConfig { enabled: true, window: CcConfig::default() }
    }
}

/// Engine tuning knobs.
#[derive(Debug, Clone)]
pub struct XferConfig {
    /// Chunk size, bytes (GridFTP-style block).
    pub chunk_bytes: u64,
    /// Streams striped per transfer.
    pub n_streams: usize,
    /// Per-stream connection setup, seconds (paid in parallel).
    pub stream_setup_s: f64,
    /// Per-chunk ack processing, seconds.
    pub ack_op_s: f64,
    /// Endpoint checksum throughput, bytes/s (each side digests once).
    /// Used for the private-time fallback when a digest side has no
    /// [`DigestSinks`] server; the DTN CPUs are provisioned at the same
    /// rate (see `workspace`).
    pub checksum_bw: f64,
    /// Retries allowed per chunk before the transfer fails.
    pub max_retries: u32,
    /// Per-stream congestion control (off by default).
    pub cc: CongestionConfig,
    /// Stream-count autotuning (off — [`TuneMode::Fixed`] — by
    /// default; `n_streams` is then used as-is). When adaptive,
    /// `n_streams` is only the *starting* width (callers seeding from a
    /// [`PathStateTable`] overwrite it with the learned width).
    pub tune: TuneConfig,
}

impl Default for XferConfig {
    fn default() -> Self {
        XferConfig {
            chunk_bytes: 4 << 20,
            n_streams: 8,
            stream_setup_s: 500e-6,
            ack_op_s: 20e-6,
            checksum_bw: 10e9,
            max_retries: 4,
            cc: CongestionConfig::default(),
            tune: TuneConfig::default(),
        }
    }
}

/// One requested bulk transfer.
#[derive(Debug, Clone)]
pub struct TransferRequest {
    /// Caller-chosen identifier (echoed in the report).
    pub id: u64,
    /// Owning collaboration (the fair-share key).
    pub owner: String,
    /// Source data center.
    pub src_dc: usize,
    /// Destination data center.
    pub dst_dc: usize,
    /// Payload size, bytes.
    pub bytes: u64,
    /// Priority class.
    pub priority: Priority,
    /// Virtual time the request was submitted.
    pub submitted_at: f64,
}

/// Congestion accounting observed on one link of a transfer's path
/// while the transfer ran — the *transfer's own* share, summed from its
/// chunk flows' flow-local counters ([`Engine::flow_link_losses`]),
/// never from link-total snapshots (those double-count a concurrent
/// transfer's losses the moment two transfers overlap on a link). This
/// is the per-path loss signal the stream-count autotuner steers by.
#[derive(Debug, Clone, PartialEq)]
pub struct PathLoss {
    /// Link name (as registered in the engine, e.g. `net.wan`).
    pub link: String,
    /// Congestion losses this transfer's flows absorbed on the link.
    pub losses: u64,
    /// Bytes those losses re-queued for retransmission.
    pub retransmit_bytes: u64,
}

/// Outcome of one completed transfer.
#[derive(Debug, Clone)]
pub struct TransferReport {
    /// Request id.
    pub id: u64,
    /// Owning collaboration.
    pub owner: String,
    /// Priority class.
    pub priority: Priority,
    /// Source data center.
    pub src_dc: usize,
    /// Destination data center.
    pub dst_dc: usize,
    /// Payload bytes delivered (every chunk verified).
    pub bytes: u64,
    /// Chunks in the transfer.
    pub chunks: u32,
    /// Streams opened over the transfer's lifetime (autotuner widening
    /// included; matches `stream_goodput.len()`).
    pub streams: usize,
    /// Chunk deliveries that had to be repeated.
    pub retried_chunks: u32,
    /// Bytes of those repeated deliveries (always < `bytes` when only
    /// some chunks fault — the whole point of chunk-level retry).
    pub retried_bytes: u64,
    /// Streams that died mid-transfer.
    pub stream_drops: u32,
    /// Congestion losses the streams absorbed (windowed flows on
    /// managed links only; see [`CongestionConfig`]).
    pub cc_losses: u64,
    /// Bytes those losses re-queued for retransmission inside the
    /// engine (distinct from `retried_bytes`, which counts whole-chunk
    /// integrity retries).
    pub cc_retransmit_bytes: u64,
    /// Virtual start time (first stream opened).
    pub started_at: f64,
    /// Virtual completion time (last chunk verified).
    pub finished_at: f64,
    /// Observed per-stream goodput, bytes/s ([`StreamSet::goodput`]):
    /// what each stripe actually yielded over its lifetime, voided
    /// deliveries excluded. Together with `path_losses` this is the
    /// signal set for an adaptive stream-count controller.
    pub stream_goodput: Vec<f64>,
    /// The transfer's own per-link congestion shares along its path, in
    /// hop order (filled by [`Flight::into_report`] from the flow-local
    /// accounting, so every execution path — blocking, batch, queue —
    /// reports identically).
    pub path_losses: Vec<PathLoss>,
    /// What the stream-count controller did (`None` under
    /// [`TuneMode::Fixed`]).
    pub tune: Option<TuneOutcome>,
}

impl TransferReport {
    /// Wall (virtual) duration.
    pub fn seconds(&self) -> f64 {
        (self.finished_at - self.started_at).max(0.0)
    }

    /// Goodput in MB/s (payload only; retries don't count).
    pub fn mbps(&self) -> f64 {
        crate::util::units::mbps(self.bytes, self.seconds())
    }
}

/// One chunk of a [`Flight`] in flight on the engine: produced by
/// [`Flight::begin_chunk`], resolved by [`Flight::finish_chunk`] once
/// its payload flow completes.
#[derive(Debug, Clone, Copy)]
pub struct FlightChunk {
    chunk: Chunk,
    cf: ChunkFlight,
    /// Flight-recorder slice for this chunk (only when a recorder is
    /// attached and the flight carries an op span); closed by
    /// [`Flight::finish_chunk`].
    span: Option<SpanId>,
}

impl FlightChunk {
    /// The engine flow carrying this chunk's payload — what an
    /// event-driven caller watches ([`Engine::flow_finish`]) to know
    /// when to call [`Flight::finish_chunk`].
    pub fn flow(&self) -> crate::engine::FlowId {
        self.cf.flow
    }
}

/// One in-flight transfer: streams + pending chunks + retry accounting.
/// Exposed to [`sched`] so concurrent transfers can interleave at chunk
/// granularity on the shared links.
#[derive(Debug)]
pub struct Flight {
    /// The request being served.
    pub req: TransferRequest,
    path: Vec<Link>,
    streams: StreamSet,
    sinks: DigestSinks,
    pending: VecDeque<Chunk>,
    attempts: Vec<u32>,
    delivered_bytes: u64,
    report: TransferReport,
    /// Op span chunk slices are parented under (flight-recorder
    /// attribution only; never affects timing).
    span: Option<SpanId>,
    /// The stream-count controller (`None` under [`TuneMode::Fixed`] —
    /// the fixed path then never touches the round accounting below).
    tuner: Option<Autotuner>,
    /// Chunks completed in the current observation round.
    round_chunks: u32,
    /// Virtual time the current round opened.
    round_started: f64,
    /// Payload bytes the current round delivered and verified.
    round_bytes: u64,
    /// `(cc_losses, cc_retransmit_bytes)` at the round open — the
    /// deltas against these are the round's flow-local loss signal.
    round_loss_base: (u64, u64),
}

impl Flight {
    /// Open streams and stage every chunk at virtual time `now`; chunk
    /// digests are private stream time (no [`DigestSinks`]).
    pub fn new(cfg: &XferConfig, net: &Network, req: &TransferRequest, now: f64) -> Flight {
        Self::with_sinks(cfg, net, req, now, DigestSinks::default())
    }

    /// [`Flight::new`] with the chunk digests charged to the given
    /// endpoint servers (the DTN service CPUs) instead of private
    /// stream time.
    pub fn with_sinks(
        cfg: &XferConfig,
        net: &Network,
        req: &TransferRequest,
        now: f64,
        sinks: DigestSinks,
    ) -> Flight {
        let chunks = chunk_spans(req.bytes, cfg.chunk_bytes);
        let width = cfg.n_streams.max(1).min(chunks.len().max(1));
        // adaptive: n_streams is only the starting width, clamped into
        // the controller's band (callers seeding a learned width have
        // already overwritten n_streams)
        let tuner = match cfg.tune.mode {
            TuneMode::Fixed => None,
            TuneMode::Adaptive => Some(Autotuner::new(cfg.tune.clone(), width)),
        };
        let width = tuner.as_ref().map_or(width, Autotuner::width);
        let streams = StreamSet::new(width, now, cfg.stream_setup_s);
        let attempts = vec![0u32; chunks.len()];
        Flight {
            req: req.clone(),
            path: net.path(req.src_dc, req.dst_dc),
            sinks,
            pending: chunks.into_iter().collect(),
            attempts,
            delivered_bytes: 0,
            report: TransferReport {
                id: req.id,
                owner: req.owner.clone(),
                priority: req.priority,
                src_dc: req.src_dc,
                dst_dc: req.dst_dc,
                bytes: req.bytes,
                chunks: 0,
                streams: width,
                retried_chunks: 0,
                retried_bytes: 0,
                stream_drops: 0,
                cc_losses: 0,
                cc_retransmit_bytes: 0,
                started_at: now,
                finished_at: now,
                stream_goodput: Vec::new(),
                path_losses: Vec::new(),
                tune: None,
            },
            streams,
            span: None,
            tuner,
            round_chunks: 0,
            round_started: now,
            round_bytes: 0,
            round_loss_base: (0, 0),
        }
    }

    /// Parent this flight's chunk slices under the given op span in the
    /// flight recorder (attribution only; no timing effect).
    pub fn set_span(&mut self, span: SpanId) {
        self.span = Some(span);
    }

    /// All chunks delivered and verified?
    pub fn is_done(&self) -> bool {
        self.pending.is_empty()
    }

    /// Payload bytes verified so far, scaled by the priority weight —
    /// the fair-share dispatch key (smallest goes next).
    pub fn weighted_service(&self) -> f64 {
        self.delivered_bytes as f64 / self.req.priority.weight()
    }

    /// Deliver one chunk: pick the earliest live stream, traverse the
    /// path, verify, and either complete the chunk or re-queue it
    /// (corrupt arrival / stream death). Errors once a chunk exhausts
    /// its retry budget.
    ///
    /// This is the blocking composition of [`Flight::begin_chunk`] +
    /// [`Engine::completion`] + [`Flight::finish_chunk`] — the single
    /// sequential-caller convenience. Event-driven callers (the batch
    /// executor) drive the halves themselves so chunks from concurrent
    /// transfers are in flight together.
    pub fn step(
        &mut self,
        cfg: &XferConfig,
        env: &mut Engine,
        faults: &mut FaultInjector,
    ) -> Result<()> {
        let Some(fc) = self.begin_chunk(cfg, env)? else {
            return Ok(());
        };
        env.completion(fc.cf.flow);
        self.finish_chunk(cfg, env, faults, fc);
        Ok(())
    }

    /// First half of [`Flight::step`]: pop the next pending chunk, pick
    /// its stream (reconnecting if every stream died), charge the
    /// sender digest and start the payload flow — without draining the
    /// event queue, so it is usable mid-drain with other transfers'
    /// chunks in flight. Returns `Ok(None)` when no chunks are pending;
    /// errors once a chunk exhausts its retry budget.
    pub fn begin_chunk(
        &mut self,
        cfg: &XferConfig,
        env: &mut Engine,
    ) -> Result<Option<FlightChunk>> {
        let Some(chunk) = self.pending.pop_front() else {
            return Ok(None);
        };
        let s = match self.streams.best_live() {
            Some(s) => s,
            None => {
                // every stream died: reconnect one and keep going
                let at = self.streams.horizon();
                self.streams.revive(0, at, cfg.stream_setup_s);
                0
            }
        };
        let idx = chunk.index as usize;
        self.attempts[idx] += 1;
        if self.attempts[idx] > cfg.max_retries + 1 {
            bail!(
                "transfer {}: chunk {} exceeded {} retries",
                self.req.id,
                chunk.index,
                cfg.max_retries
            );
        }
        let cf = self.streams.begin_chunk(env, &self.path, s, chunk.len, cfg, self.sinks);
        let span = match self.span {
            Some(parent) if env.recording() => {
                let t0 = env.flow_start_time(cf.flow);
                Some(env.begin_span(t0, format!("chunk{}", chunk.index), Some(parent), None))
            }
            _ => None,
        };
        Ok(Some(FlightChunk { chunk, cf, span }))
    }

    /// Second half of [`Flight::step`]: the chunk's flow has completed
    /// — resolve the receiver digest + ack through the stream, then run
    /// the integrity verdict (deliver, or re-queue on a forced fault /
    /// dead stream). Panics if the flow has not finished yet.
    pub fn finish_chunk(
        &mut self,
        cfg: &XferConfig,
        env: &mut Engine,
        faults: &mut FaultInjector,
        fc: FlightChunk,
    ) {
        let FlightChunk { chunk, cf, span } = fc;
        let s = cf.stream;
        let idx = chunk.index as usize;
        let t = self.streams.finish_chunk(env, &self.path, cf, cfg, self.sinks);
        if let Some(sp) = span {
            env.end_span(sp, t);
        }
        if faults.drops_stream(s, self.streams.sent(s)) {
            // the carrying stream died; the chunk is not acked and must
            // be re-sent on a surviving stream
            self.streams.kill(s);
            self.streams.discount(s, chunk.len);
            self.report.stream_drops += 1;
            self.report.retried_chunks += 1;
            self.report.retried_bytes += chunk.len;
            self.pending.push_back(chunk);
        } else if faults.corrupts(chunk.index, self.attempts[idx]) {
            // checksum mismatch at the receiver: retry just this chunk
            self.streams.discount(s, chunk.len);
            self.report.retried_chunks += 1;
            self.report.retried_bytes += chunk.len;
            self.pending.push_back(chunk);
        } else {
            self.delivered_bytes += chunk.len;
            self.report.chunks += 1;
            self.report.finished_at = self.report.finished_at.max(t);
            if self.tuner.is_some() {
                self.round_bytes += chunk.len;
            }
        }
        if self.tuner.is_some() {
            self.round_chunks += 1;
            self.maybe_tune(cfg, env, t);
        }
    }

    /// Close the observation round if it is complete and apply the
    /// controller's verdict — the chunk-boundary adaptation rule: this
    /// runs only between chunks, so a chunk in flight is never
    /// re-striped. No-op while the round is still filling or when no
    /// chunks remain to act on.
    fn maybe_tune(&mut self, cfg: &XferConfig, env: &mut Engine, now: f64) {
        let Some(tuner) = self.tuner.as_mut() else { return };
        if (self.round_chunks as usize) < tuner.width() || self.pending.is_empty() {
            return;
        }
        let obs = RoundObs {
            width: tuner.width(),
            delivered_bytes: self.round_bytes,
            elapsed_s: now - self.round_started,
            losses: self.streams.cc_losses() - self.round_loss_base.0,
            retransmit_bytes: self.streams.cc_retransmit_bytes() - self.round_loss_base.1,
        };
        let action = tuner.observe(&obs);
        let (from, to) = match action {
            TuneAction::Widen { to } => {
                let live = self.streams.live_count();
                if to > live {
                    self.streams.grow(to - live, now, cfg.stream_setup_s);
                }
                (obs.width, to)
            }
            TuneAction::Shed { to } => {
                self.streams.shed_to(to);
                (obs.width, to)
            }
            TuneAction::Hold => (obs.width, obs.width),
        };
        if from != to && env.recording() {
            self.emit_tune(env, now, from, to, &obs);
        }
        self.round_chunks = 0;
        self.round_bytes = 0;
        self.round_started = now;
        self.round_loss_base = (self.streams.cc_losses(), self.streams.cc_retransmit_bytes());
    }

    /// Recorder-only tuner-decision event (never affects timing).
    fn emit_tune(&self, env: &mut Engine, t: f64, from: usize, to: usize, obs: &RoundObs) {
        env.emit(TraceEvent::Tune {
            t,
            transfer: self.req.id,
            src_dc: self.req.src_dc,
            dst_dc: self.req.dst_dc,
            from,
            to,
            rate: obs.rate(),
            losses: obs.losses,
        });
    }

    /// Consume the flight into its report. `env` resolves the path's
    /// link names for the flow-local per-link loss attribution.
    pub fn into_report(mut self, env: &Engine) -> TransferReport {
        self.report.cc_losses = self.streams.cc_losses();
        self.report.cc_retransmit_bytes = self.streams.cc_retransmit_bytes();
        self.report.streams = self.streams.width();
        self.report.stream_goodput =
            (0..self.streams.width()).map(|s| self.streams.goodput(s)).collect();
        self.report.path_losses = self
            .path
            .iter()
            .map(|l| {
                let (losses, retransmit_bytes) =
                    self.streams.link_losses().get(&l.res.0).copied().unwrap_or((0, 0));
                PathLoss { link: env.link(l.res).name.clone(), losses, retransmit_bytes }
            })
            .collect();
        self.report.tune = self.tuner.as_ref().map(Autotuner::outcome);
        self.report
    }
}

/// The transfer engine: configuration + transfer execution.
#[derive(Debug, Clone, Default)]
pub struct XferEngine {
    /// Tuning knobs.
    pub cfg: XferConfig,
}

impl XferEngine {
    /// Engine with the given configuration.
    pub fn new(cfg: XferConfig) -> Self {
        XferEngine { cfg }
    }

    /// Run one transfer to completion starting at `now`, charging the
    /// shared network resources in `env`/`net`. Zero-byte transfers
    /// complete instantly. Chunk digests are private stream time; use
    /// [`XferEngine::transfer_with_sinks`] to charge them to the DTN
    /// service CPUs instead.
    pub fn transfer(
        &self,
        env: &mut Engine,
        net: &mut Network,
        req: &TransferRequest,
        faults: &mut FaultInjector,
        now: f64,
    ) -> Result<TransferReport> {
        self.transfer_with_sinks(env, net, req, faults, now, DigestSinks::default())
    }

    /// [`XferEngine::transfer`] with the per-chunk digests served by
    /// the endpoint DTN CPUs ([`Engine::serve`]) — integrity cost then
    /// queues behind (and delays) whatever metadata service load those
    /// CPUs are carrying, instead of being free private stream time.
    pub fn transfer_with_sinks(
        &self,
        env: &mut Engine,
        net: &mut Network,
        req: &TransferRequest,
        faults: &mut FaultInjector,
        now: f64,
        sinks: DigestSinks,
    ) -> Result<TransferReport> {
        let mut flight = Flight::with_sinks(&self.cfg, net, req, now, sinks);
        if let Some(span) = env.current_span() {
            flight.set_span(span);
        }
        net.begin_transfer(req.src_dc, req.dst_dc);
        let mut outcome = Ok(());
        while !flight.is_done() {
            if let Err(e) = flight.step(&self.cfg, env, faults) {
                outcome = Err(e);
                break;
            }
        }
        net.end_transfer(req.src_dc, req.dst_dc);
        outcome?;
        Ok(flight.into_report(env))
    }

    /// [`XferEngine::transfer_with_sinks`] with per-path width
    /// persistence: when the controller is enabled, the starting stream
    /// count is seeded from the table's learned width for
    /// `(src_dc, dst_dc)` (if any), and the transfer's tuner outcome is
    /// recorded back so the next transfer on the path warm-starts.
    #[allow(clippy::too_many_arguments)]
    pub fn transfer_tuned(
        &self,
        env: &mut Engine,
        net: &mut Network,
        req: &TransferRequest,
        faults: &mut FaultInjector,
        now: f64,
        sinks: DigestSinks,
        paths: &mut PathStateTable,
    ) -> Result<TransferReport> {
        let mut eng = self.clone();
        if eng.cfg.tune.mode == TuneMode::Adaptive {
            if let Some(w) = paths.learned_width(req.src_dc, req.dst_dc) {
                eng.cfg.n_streams = w;
            }
        }
        let report = eng.transfer_with_sinks(env, net, req, faults, now, sinks)?;
        if let Some(outcome) = &report.tune {
            paths.record(req.src_dc, req.dst_dc, outcome);
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::NetConfig;

    fn setup() -> (Engine, Network) {
        let mut env = Engine::new();
        let net = Network::build(&mut env, &NetConfig::paper_default(), 2);
        (env, net)
    }

    fn req(bytes: u64, streams: &str) -> TransferRequest {
        TransferRequest {
            id: 1,
            owner: streams.to_string(),
            src_dc: 0,
            dst_dc: 1,
            bytes,
            priority: Priority::Bulk,
            submitted_at: 0.0,
        }
    }

    fn run(env: &mut Engine, net: &mut Network, cfg: XferConfig, bytes: u64) -> TransferReport {
        let engine = XferEngine::new(cfg);
        engine
            .transfer(env, net, &req(bytes, "t"), &mut FaultInjector::none(), 0.0)
            .expect("transfer")
    }

    #[test]
    fn clean_transfer_delivers_every_chunk_once() {
        let (mut env, mut net) = setup();
        let rep = run(&mut env, &mut net, XferConfig::default(), 64 << 20);
        assert_eq!(rep.chunks, 16);
        assert_eq!(rep.retried_chunks, 0);
        assert_eq!(rep.retried_bytes, 0);
        assert_eq!(rep.bytes, 64 << 20);
        assert!(rep.finished_at > rep.started_at);
        // conservation: each link carried exactly the payload
        assert_eq!(env.link(net.wan.res).total_bytes, 64 << 20);
        assert_eq!(env.link(net.lans[0].res).total_bytes, 64 << 20);
        assert_eq!(env.link(net.lans[1].res).total_bytes, 64 << 20);
    }

    #[test]
    fn more_streams_transfer_faster_then_plateau() {
        // Acceptance (a): time strictly decreases with stream count on a
        // fixed WAN, then plateaus at the byte-serialization floor.
        let total = 256 << 20;
        let mut secs = Vec::new();
        for s in [1usize, 2, 4, 8, 32] {
            let (mut env, mut net) = setup();
            let cfg = XferConfig { n_streams: s, ..XferConfig::default() };
            let rep = run(&mut env, &mut net, cfg, total);
            secs.push(rep.seconds());
        }
        assert!(secs[0] > secs[1], "1 -> 2 streams must speed up: {secs:?}");
        assert!(secs[1] > secs[2], "2 -> 4 streams must speed up: {secs:?}");
        assert!(secs[2] > secs[3], "4 -> 8 streams must speed up: {secs:?}");
        // plateau: 8 -> 32 gains little compared to the 1 -> 8 drop
        let early_gain = secs[0] - secs[3];
        let late_gain = (secs[3] - secs[4]).max(0.0);
        assert!(
            late_gain < early_gain * 0.1,
            "late gain {late_gain} should be a plateau vs {early_gain}: {secs:?}"
        );
        // and the floor is the link serialization time
        let floor = total as f64 / NetConfig::paper_default().wan_bw;
        assert!(secs[4] >= floor, "cannot beat the wire: {} < {floor}", secs[4]);
    }

    #[test]
    fn corrupt_chunk_retries_only_that_chunk() {
        // Acceptance (b): retried bytes < total bytes.
        let (mut env, mut net) = setup();
        let engine = XferEngine::new(XferConfig::default());
        let mut faults = FaultInjector::none();
        faults.force_corrupt(3);
        let rep = engine
            .transfer(&mut env, &mut net, &req(64 << 20, "c"), &mut faults, 0.0)
            .expect("transfer");
        assert_eq!(rep.chunks, 16, "all chunks must eventually deliver");
        assert_eq!(rep.retried_chunks, 1);
        assert_eq!(rep.retried_bytes, 4 << 20);
        assert!(
            rep.retried_bytes < rep.bytes,
            "must not re-send the whole file"
        );
        // the retried chunk's bytes crossed the wire twice
        assert_eq!(env.link(net.wan.res).total_bytes, (64 << 20) + (4 << 20));
    }

    #[test]
    fn dropped_stream_reassigns_chunks() {
        let (mut env, mut net) = setup();
        let engine = XferEngine::new(XferConfig { n_streams: 4, ..XferConfig::default() });
        let mut faults = FaultInjector::none();
        faults.force_drop(0, 2);
        let rep = engine
            .transfer(&mut env, &mut net, &req(64 << 20, "d"), &mut faults, 0.0)
            .expect("transfer");
        assert_eq!(rep.stream_drops, 1);
        assert_eq!(rep.chunks, 16);
        assert!(rep.retried_bytes >= 4 << 20, "the lost chunk was re-sent");
    }

    #[test]
    fn total_stream_loss_reconnects() {
        let (mut env, mut net) = setup();
        let engine = XferEngine::new(XferConfig { n_streams: 2, ..XferConfig::default() });
        let mut faults = FaultInjector::none();
        faults.force_drop(0, 1);
        faults.force_drop(1, 1);
        let rep = engine
            .transfer(&mut env, &mut net, &req(32 << 20, "r"), &mut faults, 0.0)
            .expect("transfer survives total stream loss");
        assert_eq!(rep.stream_drops, 2);
        assert_eq!(rep.chunks, 8);
    }

    #[test]
    fn persistent_corruption_fails_after_budget() {
        let (mut env, mut net) = setup();
        let engine = XferEngine::new(XferConfig { max_retries: 2, ..XferConfig::default() });
        let mut faults = FaultInjector::with_seed(1);
        faults.corrupt_rate = 1.0; // every delivery corrupt
        let err = engine
            .transfer(&mut env, &mut net, &req(8 << 20, "x"), &mut faults, 0.0)
            .unwrap_err();
        assert!(err.to_string().contains("retries"), "{err}");
    }

    #[test]
    fn zero_byte_transfer_is_instant() {
        let (mut env, mut net) = setup();
        let rep = run(&mut env, &mut net, XferConfig::default(), 0);
        assert_eq!(rep.chunks, 0);
        assert_eq!(rep.seconds(), 0.0);
    }

    #[test]
    fn same_dc_transfer_stays_on_lan() {
        let (mut env, mut net) = setup();
        let engine = XferEngine::new(XferConfig::default());
        let mut r = req(16 << 20, "l");
        r.dst_dc = 0;
        engine
            .transfer(&mut env, &mut net, &r, &mut FaultInjector::none(), 0.0)
            .expect("transfer");
        assert_eq!(env.link(net.wan.res).total_bytes, 0);
        assert_eq!(env.link(net.lans[0].res).total_bytes, 16 << 20);
    }
}
